"""Frozen pre-refactor scalar sampler implementations (the PR-1-era hot path).

These are verbatim copies of the samplers as they existed before the columnar
observation backbone landed: every ``ask`` re-materializes the full trial
history as ``FrozenTrial`` lists and loops per-parameter in scalar numpy.

They exist for two purposes only:

* the seeded **sample-parity suite** (``tests/test_vectorized_parity.py``)
  asserts the vectorized samplers produce bit-identical samples, and
* the **ask-throughput benchmark** (``benchmarks/samplers.py``) measures the
  speedup of the columnar path against this baseline.

Do not modify and do not use in new code.
"""

from __future__ import annotations

import itertools
import math
from typing import TYPE_CHECKING, Any, Callable, Mapping, Sequence

import numpy as np

from ..distributions import (
    BaseDistribution,
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
)
from ..frozen import FrozenTrial, StudyDirection, TrialState
from ..search_space import IntersectionSearchSpace
from .base import BaseSampler
from .cmaes import CMA

if TYPE_CHECKING:
    from ..study import Study

__all__ = [
    "LegacyRandomSampler",
    "LegacyGridSampler",
    "LegacyTPESampler",
    "LegacyCmaEsSampler",
    "LegacyGPSampler",
]

EPS = 1e-12

_GRID_KEY = "grid_sampler:grid_id"


def round_to_step(x: float, low: float, high: float, step: float | int) -> float:
    return low + round((x - low) / step) * step


def sample_uniform_internal(rng: np.random.RandomState, dist: BaseDistribution) -> float:
    """Pre-refactor scalar uniform sample in internal representation."""
    if isinstance(dist, FloatDistribution):
        if dist.log:
            return float(np.exp(rng.uniform(np.log(dist.low), np.log(dist.high))))
        if dist.step is not None:
            n = int(np.floor((dist.high - dist.low) / dist.step + 1e-12)) + 1
            return float(dist.low + rng.randint(n) * dist.step)
        return float(rng.uniform(dist.low, dist.high))
    if isinstance(dist, IntDistribution):
        if dist.log:
            lo, hi = np.log(dist.low - 0.5), np.log(dist.high + 0.5)
            v = int(np.clip(np.round(np.exp(rng.uniform(lo, hi))), dist.low, dist.high))
            return float(v)
        n = (dist.high - dist.low) // dist.step + 1
        return float(dist.low + rng.randint(n) * dist.step)
    if isinstance(dist, CategoricalDistribution):
        return float(rng.randint(len(dist.choices)))
    raise TypeError(f"unknown distribution {dist!r}")


class LegacyRandomSampler(BaseSampler):
    def __init__(self, seed: int | None = None):
        self._rng = np.random.RandomState(seed)

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        internal = sample_uniform_internal(self._rng, param_distribution)
        return param_distribution.to_external_repr(internal)


class LegacyGridSampler(BaseSampler):
    def __init__(self, search_space: Mapping[str, Sequence[Any]], seed: int | None = None):
        self._space = {k: list(v) for k, v in sorted(search_space.items())}
        self._grid = list(itertools.product(*self._space.values()))
        self._rng = np.random.RandomState(seed)

    def __len__(self) -> int:
        return len(self._grid)

    def _taken(self, study: "Study") -> set[int]:
        taken: set[int] = set()
        for t in study.get_trials(deepcopy=False):
            gid = t.system_attrs.get(_GRID_KEY)
            if gid is not None and (t.state.is_finished() or t.state == TrialState.RUNNING):
                taken.add(int(gid))
        return taken

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        taken = self._taken(study)
        free = [i for i in range(len(self._grid)) if i not in taken]
        if not free:
            gid = int(self._rng.randint(len(self._grid)))
        else:
            gid = free[0]
        study._storage.set_trial_system_attr(trial.trial_id, _GRID_KEY, gid)
        return dict(zip(self._space.keys(), self._grid[gid]))

    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        return {}

    def sample_independent(
        self, study: "Study", trial: FrozenTrial, param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        internal = sample_uniform_internal(self._rng, param_distribution)
        return param_distribution.to_external_repr(internal)


# -- legacy TPE ------------------------------------------------------------------


def default_gamma(n: int) -> int:
    return min(int(np.ceil(0.1 * n)), 25)


def default_weights(n: int) -> np.ndarray:
    if n == 0:
        return np.asarray([])
    if n < 25:
        return np.ones(n)
    ramp = np.linspace(1.0 / n, 1.0, n - 25)
    flat = np.ones(25)
    return np.concatenate([ramp, flat])


class _LegacyParzenEstimator:
    """1-D truncated-Gaussian mixture over [low, high] (+ a wide prior)."""

    def __init__(
        self,
        mus: np.ndarray,
        low: float,
        high: float,
        weights: np.ndarray,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        magic_clip: bool = True,
    ):
        mus = np.asarray(mus, dtype=float)
        order = np.argsort(mus)
        mus = mus[order]
        weights = np.asarray(weights, dtype=float)[order]

        if consider_prior or len(mus) == 0:
            prior_mu = 0.5 * (low + high)
            prior_sigma = high - low if high > low else 1.0
            idx = np.searchsorted(mus, prior_mu)
            mus = np.insert(mus, idx, prior_mu)
            weights = np.insert(weights, idx, prior_weight)
            prior_pos = idx
        else:
            prior_pos = None

        n = len(mus)
        sigmas = np.empty(n)
        if n == 1:
            sigmas[0] = high - low if high > low else 1.0
        else:
            padded = np.concatenate([[low], mus, [high]])
            left = mus - padded[:-2]
            right = padded[2:] - mus
            sigmas = np.maximum(left, right)
        if prior_pos is not None:
            sigmas[prior_pos] = high - low if high > low else 1.0
        maxsigma = high - low if high > low else 1.0
        minsigma = maxsigma / min(100.0, 1.0 + n) if magic_clip else EPS
        self.mus = mus
        self.sigmas = np.clip(sigmas, minsigma, maxsigma)
        self.weights = weights / max(weights.sum(), EPS)
        self.low = low
        self.high = high

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        comp = rng.choice(len(self.mus), size=size, p=self.weights)
        out = np.empty(size)
        for i, c in enumerate(comp):
            v = rng.normal(self.mus[c], self.sigmas[c])
            for _ in range(16):
                if self.low <= v <= self.high:
                    break
                v = rng.normal(self.mus[c], self.sigmas[c])
            out[i] = float(np.clip(v, self.low, self.high))
        return out

    def log_pdf(self, xs: np.ndarray) -> np.ndarray:
        xs = np.asarray(xs, dtype=float)[:, None]
        mus = self.mus[None, :]
        sigmas = self.sigmas[None, :]
        z = _normal_cdf((self.high - mus) / sigmas) - _normal_cdf((self.low - mus) / sigmas)
        z = np.maximum(z, EPS)
        log_comp = (
            -0.5 * ((xs - mus) / sigmas) ** 2
            - np.log(sigmas)
            - 0.5 * math.log(2 * math.pi)
            - np.log(z)
        )
        log_w = np.log(self.weights[None, :] + EPS)
        return _logsumexp(log_comp + log_w, axis=1)


def _normal_cdf(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + np.vectorize(math.erf)(np.asarray(x) / math.sqrt(2.0)))


def _logsumexp(a: np.ndarray, axis: int) -> np.ndarray:
    m = np.max(a, axis=axis, keepdims=True)
    return (m + np.log(np.sum(np.exp(a - m), axis=axis, keepdims=True))).squeeze(axis)


class LegacyTPESampler(BaseSampler):
    def __init__(
        self,
        n_startup_trials: int = 10,
        n_ei_candidates: int = 24,
        gamma: Callable[[int], int] = default_gamma,
        weights: Callable[[int], np.ndarray] = default_weights,
        seed: int | None = None,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        consider_magic_clip: bool = True,
        consider_pruned_trials: bool = False,
    ):
        self._n_startup = n_startup_trials
        self._n_ei = n_ei_candidates
        self._gamma = gamma
        self._weights = weights
        self._rng = np.random.RandomState(seed)
        self._consider_prior = consider_prior
        self._prior_weight = prior_weight
        self._magic_clip = consider_magic_clip
        self._consider_pruned = consider_pruned_trials

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)

    def _observations(
        self, study: "Study", param_name: str
    ) -> tuple[np.ndarray, np.ndarray, list[BaseDistribution]]:
        values, losses, dists = [], [], []
        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
        states = (
            (TrialState.COMPLETE, TrialState.PRUNED)
            if self._consider_pruned
            else (TrialState.COMPLETE,)
        )
        for t in study.get_trials(deepcopy=False, states=states):
            if param_name not in t.params:
                continue
            if t.state == TrialState.COMPLETE:
                if t.values is None:
                    continue
                loss = sign * t.values[0]
            else:
                if not t.intermediate_values:
                    continue
                loss = sign * t.intermediate_values[t.last_step]
            if not np.isfinite(loss):
                continue
            dist = t.distributions[param_name]
            values.append(dist.to_internal_repr(t.params[param_name]))
            losses.append(loss)
            dists.append(dist)
        return np.asarray(values), np.asarray(losses), dists

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        if len(study.directions) > 1:
            internal = sample_uniform_internal(self._rng, param_distribution)
            return param_distribution.to_external_repr(internal)
        values, losses, _ = self._observations(study, param_name)
        if len(values) < self._n_startup:
            internal = sample_uniform_internal(self._rng, param_distribution)
            return param_distribution.to_external_repr(internal)

        n = len(values)
        n_below = self._gamma(n)
        order = np.argsort(losses, kind="stable")
        below_idx, above_idx = order[:n_below], order[n_below:]
        below, above = values[below_idx], values[above_idx]
        w_all = self._weights(n)

        w_below = np.asarray([w_all[i] for i in below_idx])
        w_above = np.asarray([w_all[i] for i in above_idx])

        if isinstance(param_distribution, CategoricalDistribution):
            internal = self._sample_categorical(param_distribution, below, above, w_below, w_above)
        else:
            internal = self._sample_numeric(param_distribution, below, above, w_below, w_above)
        return param_distribution.to_external_repr(internal)

    def _transform(self, dist: BaseDistribution, xs: np.ndarray) -> np.ndarray:
        if getattr(dist, "log", False):
            return np.log(np.maximum(xs, EPS))
        return xs

    def _untransform(self, dist: BaseDistribution, xs: np.ndarray) -> np.ndarray:
        if getattr(dist, "log", False):
            return np.exp(xs)
        return xs

    def _bounds(self, dist: BaseDistribution) -> tuple[float, float]:
        low, high = float(dist.low), float(dist.high)
        if isinstance(dist, IntDistribution):
            low, high = low - 0.5, high + 0.5
            if dist.log:
                low = max(low, 0.5)
        if getattr(dist, "log", False):
            return math.log(low), math.log(high)
        return low, high

    def _sample_numeric(
        self,
        dist: BaseDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
    ) -> float:
        low, high = self._bounds(dist)
        l_est = _LegacyParzenEstimator(
            self._transform(dist, below), low, high, w_below,
            self._consider_prior, self._prior_weight, self._magic_clip,
        )
        g_est = _LegacyParzenEstimator(
            self._transform(dist, above), low, high, w_above,
            self._consider_prior, self._prior_weight, self._magic_clip,
        )
        cands = l_est.sample(self._rng, self._n_ei)
        score = l_est.log_pdf(cands) - g_est.log_pdf(cands)
        best = cands[int(np.argmax(score))]
        x = float(self._untransform(dist, np.asarray([best]))[0])
        if isinstance(dist, IntDistribution):
            x = float(np.clip(round_to_step(x, dist.low, dist.high, dist.step), dist.low, dist.high))
        elif isinstance(dist, FloatDistribution):
            if dist.step is not None:
                x = float(np.clip(round_to_step(x, dist.low, dist.high, dist.step), dist.low, dist.high))
            else:
                x = float(np.clip(x, dist.low, dist.high))
        return x

    def _sample_categorical(
        self,
        dist: CategoricalDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
    ) -> float:
        k = len(dist.choices)

        def weighted_probs(idxs: np.ndarray, ws: np.ndarray) -> np.ndarray:
            counts = np.full(k, self._prior_weight)
            for i, w in zip(idxs.astype(int), ws):
                counts[i] += w
            return counts / counts.sum()

        p_l = weighted_probs(below, w_below)
        p_g = weighted_probs(above, w_above)
        cands = self._rng.choice(k, size=self._n_ei, p=p_l)
        score = np.log(p_l[cands] + EPS) - np.log(p_g[cands] + EPS)
        return float(cands[int(np.argmax(score))])


# -- legacy CMA-ES ---------------------------------------------------------------


def _to_unit(dist: BaseDistribution, external: Any) -> float:
    v = dist.to_internal_repr(external)
    if isinstance(dist, (FloatDistribution, IntDistribution)):
        lo, hi = float(dist.low), float(dist.high)
        if dist.log:
            lo, hi = math.log(lo), math.log(hi)
            v = math.log(max(v, 1e-300))
        return (v - lo) / (hi - lo) if hi > lo else 0.5
    return v


def _from_unit(dist: BaseDistribution, u: float) -> Any:
    u = float(np.clip(u, 0.0, 1.0))
    lo, hi = float(dist.low), float(dist.high)
    if dist.log:
        lo_, hi_ = math.log(lo), math.log(hi)
        v = math.exp(lo_ + u * (hi_ - lo_))
    else:
        v = lo + u * (hi - lo)
    if isinstance(dist, IntDistribution):
        return int(np.clip(round_to_step(v, dist.low, dist.high, dist.step), dist.low, dist.high))
    if isinstance(dist, FloatDistribution) and dist.step is not None:
        return float(np.clip(round_to_step(v, dist.low, dist.high, dist.step), dist.low, dist.high))
    return float(np.clip(v, lo, hi))


class LegacyCmaEsSampler(BaseSampler):
    def __init__(
        self,
        warmup_trials: int = 40,
        independent_sampler: BaseSampler | None = None,
        seed: int | None = None,
        sigma0: float = 0.25,
    ):
        self._warmup = warmup_trials
        self._independent = independent_sampler or LegacyRandomSampler(seed=seed)
        self._seed = seed
        self._sigma0 = sigma0
        self._space_calc = IntersectionSearchSpace()

    def reseed_rng(self, seed: int | None = None) -> None:
        self._seed = seed
        self._independent.reseed_rng(seed)

    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        space = self._space_calc.calculate(study)
        out = {}
        for name, dist in space.items():
            if isinstance(dist, CategoricalDistribution) or dist.single():
                continue
            out[name] = dist
        return out if len(out) >= 2 else {}

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        if not search_space:
            return {}
        completed = [
            t
            for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,))
            if t.values is not None
            and all(n in t.params for n in search_space)
        ]
        if len(completed) < self._warmup:
            return {}

        names = sorted(search_space.keys())
        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0

        cma = CMA(
            mean=np.full(len(names), 0.5),
            sigma=self._sigma0,
            seed=self._seed,
        )
        replay = completed[self._warmup - 1 :] if self._warmup > 0 else completed
        batch: list[tuple[np.ndarray, float]] = []
        for t in replay:
            x = np.array(
                [_to_unit(search_space[n], t.params[n]) for n in names], dtype=float
            )
            batch.append((x, sign * t.values[0]))
            if len(batch) == cma.popsize:
                cma.tell(batch)
                batch = []

        rng = np.random.RandomState(
            None if self._seed is None else (self._seed + 7919 * trial.number)
        )
        x = cma.ask(rng)
        return {n: _from_unit(search_space[n], float(v)) for n, v in zip(names, x)}

    def sample_independent(
        self, study: "Study", trial: FrozenTrial, param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        return self._independent.sample_independent(
            study, trial, param_name, param_distribution
        )


# -- legacy GP -------------------------------------------------------------------


def _matern52(X: np.ndarray, Y: np.ndarray, ls: float) -> np.ndarray:
    d = np.sqrt(np.maximum(((X[:, None, :] - Y[None, :, :]) ** 2).sum(-1), 1e-30)) / ls
    s5 = math.sqrt(5.0)
    return (1 + s5 * d + 5.0 / 3.0 * d * d) * np.exp(-s5 * d)


def _ncdf(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1 + np.vectorize(math.erf)(x / math.sqrt(2)))


def _npdf(x: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * x * x) / math.sqrt(2 * math.pi)


class LegacyGPSampler(BaseSampler):
    def __init__(
        self,
        n_startup_trials: int = 10,
        n_candidates: int = 512,
        seed: int | None = None,
        noise: float = 1e-6,
    ):
        self._n_startup = n_startup_trials
        self._n_candidates = n_candidates
        self._rng = np.random.RandomState(seed)
        self._noise = noise
        self._fallback = LegacyRandomSampler(seed=seed)
        self._space_calc = IntersectionSearchSpace()

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)
        self._fallback.reseed_rng(seed)

    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        space = self._space_calc.calculate(study)
        return {
            n: d
            for n, d in space.items()
            if not isinstance(d, CategoricalDistribution) and not d.single()
        }

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        if not search_space:
            return {}
        names = sorted(search_space)
        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
        X, y = [], []
        for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,)):
            if t.values is None or not all(n in t.params for n in names):
                continue
            X.append([_to_unit(search_space[n], t.params[n]) for n in names])
            y.append(sign * t.values[0])
        if len(X) < self._n_startup:
            return {}
        X = np.asarray(X)
        y = np.asarray(y)
        mu, std = y.mean(), max(y.std(), 1e-12)
        yz = (y - mu) / std

        best_ls, best_ml = 0.5, -np.inf
        for ls in (0.1, 0.2, 0.5, 1.0):
            K = _matern52(X, X, ls) + self._noise * np.eye(len(X))
            try:
                L = np.linalg.cholesky(K)
            except np.linalg.LinAlgError:
                continue
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yz))
            ml = -0.5 * yz @ alpha - np.log(np.diag(L)).sum()
            if ml > best_ml:
                best_ml, best_ls = ml, ls
        ls = best_ls
        K = _matern52(X, X, ls) + self._noise * np.eye(len(X))
        L = np.linalg.cholesky(K + 1e-10 * np.eye(len(X)))
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, yz))

        C = self._rng.uniform(size=(self._n_candidates, len(names)))
        Ks = _matern52(C, X, ls)
        mean = Ks @ alpha
        v = np.linalg.solve(L, Ks.T)
        var = np.maximum(1.0 - (v**2).sum(0), 1e-12)
        sd = np.sqrt(var)
        best = yz.min()
        z = (best - mean) / sd
        ei = sd * (z * _ncdf(z) + _npdf(z))
        x = C[int(np.argmax(ei))]
        return {n: _from_unit(search_space[n], float(u)) for n, u in zip(names, x)}

    def sample_independent(
        self, study: "Study", trial: FrozenTrial, param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        return self._fallback.sample_independent(study, trial, param_name, param_distribution)
