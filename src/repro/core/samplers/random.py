"""Uniform-random independent sampler (the paper's §5.1 baseline)."""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

import numpy as np

from ..distributions import BaseDistribution, CategoricalDistribution
from ..frozen import FrozenTrial
from .base import BaseSampler, sample_uniform_internal

if TYPE_CHECKING:
    from ..search_space import ParamGroup
    from ..study import Study

__all__ = ["RandomSampler"]


class RandomSampler(BaseSampler):
    def __init__(self, seed: int | None = None):
        self._rng = np.random.RandomState(seed)

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)

    def sample_joint(
        self, study: "Study", group: "ParamGroup", n: int,
        trial_ids: "list[int] | None" = None,
        first_number: "int | None" = None,
    ) -> np.ndarray:
        """Uniform block: one vectorized ``sample_uniform`` draw per column
        instead of n x p scalar RNG calls."""
        block = np.empty((n, len(group.names)))
        for j, name in enumerate(group.names):
            dist = group.dists[name]
            draws = dist.sample_uniform(self._rng, n)
            if isinstance(dist, CategoricalDistribution):
                block[:, j] = draws  # already model-space choice indices
            else:
                block[:, j] = dist.to_internal(draws)
        return block

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        internal = sample_uniform_internal(self._rng, param_distribution)
        return param_distribution.to_external_repr(internal)
