"""``Study`` — one optimization process (paper §2).

A study owns a sampler, a pruner and a storage handle.  ``optimize`` runs the
define-by-run objective repeatedly; distributed optimization is *the same
call from N processes against the same storage* (paper Fig. 7) — there is no
coordinator.  ``ask``/``tell`` expose the trial lifecycle for custom loops
(e.g. the tune scheduler placing trials onto mesh slices).
"""

from __future__ import annotations

import datetime
import logging
import math
import threading
import time
import warnings
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from ..kernels import ops as kops
from . import telemetry
from .exceptions import DuplicatedStudyError, TrialPruned
from .frozen import FrozenTrial, StudyDirection, TrialState
from .log import get_logger
from .pruners import BasePruner, NopPruner
from .records import IntermediateValueStore, ObservationStore
from .samplers import BaseSampler, TPESampler
from .search_space import observed_groups
from .storage import BaseStorage, get_storage
from .trial import Trial

__all__ = ["Study", "create_study", "load_study", "delete_study"]

ObjectiveFunc = Callable[[Trial], float]

_log = get_logger(__name__)


class Study:
    def __init__(
        self,
        study_name: str,
        storage: "str | BaseStorage | None" = None,
        sampler: BaseSampler | None = None,
        pruner: BasePruner | None = None,
        engine: str = "auto",
    ):
        """``engine`` selects the compute path for the study's own columnar
        reductions (``pareto_front``) and the default sampler:
        ``"auto"`` dispatches to the device past the shared work thresholds,
        ``"numpy"``/``"jax"``/``"pallas"`` force a path (``kernels/ops.py``).
        An explicitly passed sampler keeps its own ``engine`` setting."""
        self._storage = get_storage(storage)
        self.study_name = study_name
        self._study_id = self._storage.get_study_id_from_name(study_name)
        self._engine = kops.validate_engine(engine)
        self.sampler = sampler or TPESampler(engine=engine)
        self.pruner = pruner or NopPruner()
        self._stop_requested = False
        self._records: ObservationStore | None = None
        self._ivs: IntermediateValueStore | None = None
        # joint-sampling state: group decomposition memoized per store
        # version; the miss log fires once per study, not per trial
        self._groups_cache: "tuple[int, list] | None" = None
        self._joint_miss_logged = False
        # directions are immutable after creation: fetch once here so the
        # fused report path never pays an extra storage call for them
        self._directions: list[StudyDirection] = (
            self._storage.get_study_directions(self._study_id)
        )
        # heartbeat configuration (fault tolerance; see DESIGN.md)
        self.heartbeat_interval: float | None = None
        self.failed_trial_grace: float = 60.0

    # -- directions ----------------------------------------------------------------

    @property
    def directions(self) -> list[StudyDirection]:
        return list(self._directions)

    @property
    def direction(self) -> StudyDirection:
        ds = self.directions
        if len(ds) != 1:
            raise RuntimeError("multi-objective study; use .directions")
        return ds[0]

    # -- trial access ----------------------------------------------------------------

    @property
    def trials(self) -> list[FrozenTrial]:
        return self.get_trials()

    def get_trials(
        self,
        deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
    ) -> list[FrozenTrial]:
        return self._storage.get_all_trials(self._study_id, deepcopy=deepcopy, states=states)

    def observations(self) -> ObservationStore:
        """The study's columnar observation store: finished-trial history as
        number-ordered arrays (one model-space matrix + values/states
        vectors), refreshed incrementally.  This is the substrate every
        array-native sampler reads instead of ``get_trials`` — see
        ``core/records.py``."""
        if self._records is None:
            self._records = ObservationStore(self._storage, self._study_id)
        self._records.refresh()
        return self._records

    def intermediate_values(self, objective: "int | None" = None):
        """The study's columnar intermediate-value store: every trial's
        reported values as one revision-gated ``(n_trials, n_steps)``
        NaN-padded matrix with cached best-so-far prefixes — the substrate
        the vectorized pruner stack reads instead of re-walking
        ``intermediate_values`` dicts (see ``core/records.py``).

        With ``objective=k`` returns that objective's ``(n_trials, n_steps)``
        learning-curve matrix instead of the store — vector reports read
        from the per-objective tensor, scalar reports count as objective 0
        (see ``IntermediateValueStore.objective_matrix``)."""
        if self._ivs is None:
            self._ivs = IntermediateValueStore(self._storage, self._study_id)
        self._ivs.refresh()
        if objective is None:
            return self._ivs
        return self._ivs.objective_matrix(int(objective))

    @property
    def best_trial(self) -> FrozenTrial:
        best = None
        sign = 1.0 if self.direction == StudyDirection.MINIMIZE else -1.0
        for t in self.get_trials(deepcopy=False, states=(TrialState.COMPLETE,)):
            if t.values is None or not math.isfinite(t.values[0]):
                continue
            if best is None or sign * t.values[0] < sign * best.values[0]:
                best = t
        if best is None:
            raise ValueError("no completed trials yet")
        return best.copy()

    @property
    def best_params(self) -> dict[str, Any]:
        return self.best_trial.params

    @property
    def best_value(self) -> float:
        return self.best_trial.value

    @property
    def best_trials(self) -> list[FrozenTrial]:
        """Pareto-optimal completed trials, computed on the multi-objective
        engine: one vectorized dominance reduction over the observation
        store's values matrix (``core/moo.py``) instead of the historical
        O(n²·m) pure-Python pairwise loop (kept as
        :func:`_pairwise_best_trials` and pinned bit-identical by
        ``tests/test_moo.py``)."""
        front_numbers = set(self.pareto_front()[1].tolist())
        directions = self.directions
        out = []
        for t in self.get_trials(deepcopy=False, states=(TrialState.COMPLETE,)):
            if t.values is None or len(t.values) != len(directions):
                continue
            if t.number in front_numbers:
                out.append(t.copy())
        return out

    def pareto_front(self) -> "tuple[np.ndarray, np.ndarray]":
        """``(values, numbers)`` of the non-dominated COMPLETE trials, as
        arrays straight off the columnar engine: ``values`` is the
        ``(n_front, n_objectives)`` slice of the observation store's values
        matrix (raw study orientation, number-ordered), ``numbers`` the
        matching trial numbers.  No ``FrozenTrial`` materialization — this is
        the fast path dashboards, samplers and benchmarks read."""
        from . import moo

        store = self.observations()
        directions = self.directions
        # one consistent snapshot: a concurrent refresh from another worker
        # thread must not pair this mask with a re-sorted values matrix
        _, states, V, arity, numbers, _ = store.snapshot_mo()
        mask = (states == int(TrialState.COMPLETE)) & (arity == len(directions))
        front = moo.pareto_front_mask(
            moo.loss_matrix(V, directions), mask=mask, engine=self._engine
        )
        return V[front], numbers[front]

    # -- attrs -------------------------------------------------------------------------

    @property
    def user_attrs(self) -> dict[str, Any]:
        return self._storage.get_study_user_attrs(self._study_id)

    @property
    def system_attrs(self) -> dict[str, Any]:
        return self._storage.get_study_system_attrs(self._study_id)

    def set_user_attr(self, key: str, value: Any) -> None:
        self._storage.set_study_user_attr(self._study_id, key, value)

    def set_system_attr(self, key: str, value: Any) -> None:
        self._storage.set_study_system_attr(self._study_id, key, value)

    # -- ask / tell ----------------------------------------------------------------------

    def ask(self, n: int | None = None) -> "Trial | list[Trial]":
        """Create a new trial (claiming an enqueued WAITING one if present).

        ``ask(n)`` is the batched form: it claims up to ``n`` enqueued
        WAITING trials, creates the remainder in one storage round trip
        (``create_new_trials`` batches over ``remote://``), and returns a
        list of ``n`` trials.  Distributed workers and the tune scheduler use
        it to seed a whole wave of trials per round trip."""
        with telemetry.span("study.ask"):
            if n is None:
                for t in self.get_trials(deepcopy=False, states=(TrialState.WAITING,)):
                    if self._storage.set_trial_state_values(t.trial_id, TrialState.RUNNING):
                        return Trial(self, t.trial_id)
                trial_id = self._storage.create_new_trial(self._study_id)
                return Trial(self, trial_id)
            if n < 0:
                raise ValueError(f"ask(n) needs n >= 0, got {n}")
            trials: list[Trial] = []
            fixed: set[int] = set()  # claimed enqueued trials with fixed params
            for t in self.get_trials(deepcopy=False, states=(TrialState.WAITING,)):
                if len(trials) == n:
                    break
                if self._storage.set_trial_state_values(t.trial_id, TrialState.RUNNING):
                    trials.append(Trial(self, t.trial_id))
                    if t.system_attrs.get("fixed_params"):
                        fixed.add(t.trial_id)
            for trial_id in self._storage.create_new_trials(self._study_id, n - len(trials)):
                trials.append(Trial(self, trial_id))
            # enqueued configurations replay their fixed params, never the block:
            # presampling them would waste draws and, worse, consume stateful
            # joint side effects (a grid cell claimed for a trial that will not
            # evaluate it) — they keep the scalar path exactly as ask() would
            sampled = [t for t in trials if t._trial_id not in fixed]
            if sampled:
                self._presample_joint(sampled)
            return trials

    # -- joint (block) sampling -----------------------------------------------

    def observed_param_groups(self) -> list:
        """Group decomposition of the observed search space (connected
        components of co-observed parameters), memoized per observation-store
        version — see ``search_space.observed_groups``."""
        store = self.observations()
        cached = self._groups_cache
        if cached is not None and cached[0] == store.version:
            return cached[1]
        groups = observed_groups(store)
        self._groups_cache = (store.version, groups)
        return groups

    def _presample_joint(self, trials: "list[Trial]") -> None:
        """One ``sample_joint`` call per observed parameter group covers the
        whole wave: each pending trial gets its slice of the returned
        ``(n, n_params)`` block attached, and its ``suggest_*`` calls resolve
        from the slice with no further sampler work (see ``Trial._sample``).
        Samplers without a joint model (or with ``multivariate=False``)
        decline and the per-trial define-by-run path runs untouched."""
        sampler = self.sampler
        if not sampler.joint_enabled():
            return
        with telemetry.span("study.presample_joint"):
            self._presample_joint_inner(trials, sampler)

    def _presample_joint_inner(self, trials: "list[Trial]", sampler: BaseSampler) -> None:
        groups = self.observed_param_groups()
        if not groups:
            return
        n = len(trials)
        trial_ids = [t._trial_id for t in trials]
        # the wave's RNG key: the first pending trial's storage-assigned
        # number (one cached get_trial at most).  Concurrent workers claim
        # disjoint numbers, so their joint blocks draw from distinct streams
        # even with identical histories — keying on history length could not
        # distinguish them (ROADMAP PR-4 follow-up).
        try:
            first_number = trials[0].number
        except Exception:  # pragma: no cover - racing delete
            first_number = None
        rows: list[dict[str, float]] = [{} for _ in trials]
        dists: dict[str, Any] = {}
        any_block = False
        kwargs: dict[str, Any] = {"trial_ids": trial_ids}
        if self._sampler_takes_first_number(sampler):
            kwargs["first_number"] = first_number
        for group in groups:
            block = sampler.sample_joint(self, group, n, **kwargs)
            if block is None:
                # declined whole group (startup/warmup): record NaN cells so
                # the shim falls back silently — only parameters *no* group
                # predicted (dynamic branches) count as misses worth logging
                for name in group.names:
                    dists[name] = group.dists[name]
                    for row in rows:
                        row[name] = float("nan")
                continue
            block = np.asarray(block, dtype=float)
            if block.shape != (n, len(group.names)):
                raise ValueError(
                    f"sample_joint returned shape {block.shape}, expected "
                    f"{(n, len(group.names))} for group {group.names}"
                )
            any_block = True
            for j, name in enumerate(group.names):
                dists[name] = group.dists[name]
                for i in range(n):
                    rows[i][name] = float(block[i, j])
        if any_block:
            for trial, row in zip(trials, rows):
                trial._joint = row
                trial._joint_dists = dists

    def _sampler_takes_first_number(self, sampler: BaseSampler) -> bool:
        """Custom samplers may predate the ``first_number`` kwarg of the
        block contract: probe the signature once per study (not
        TypeError-catch per call, which would swallow genuine errors inside
        the sampler)."""
        cached = self.__dict__.get("_joint_sig_ok")
        if cached is not None and cached[0] is type(sampler):
            return cached[1]
        import inspect

        ok = False
        try:
            ok = "first_number" in inspect.signature(sampler.sample_joint).parameters
        except (TypeError, ValueError):  # pragma: no cover - exotic callables
            pass
        self.__dict__["_joint_sig_ok"] = (type(sampler), ok)
        return ok

    def _note_joint_miss(self, name: str, reason: str) -> None:
        """Joint-block prediction miss (dynamic branch / drifted domain):
        log once per study — a per-trial warning would fire on every wave of
        a branching objective and drown real signal."""
        if self._joint_miss_logged:
            return
        self._joint_miss_logged = True
        telemetry.inc("study.joint_miss")
        # the per-study flag above already dedupes; a global log_once keyed
        # on id(self) would go silent when a dead study's id gets reused
        _log.log(
            logging.INFO,
            "study %r [worker %s]: joint block missed parameter %r (%s); "
            "falling back to per-trial scalar sampling for divergent "
            "parameters (logged once per study)",
            self.study_name, telemetry.worker_id(), name, reason,
        )

    def tell(
        self,
        trial: "Trial | int",
        values: "float | Sequence[float] | None" = None,
        state: TrialState = TrialState.COMPLETE,
    ) -> None:
        with telemetry.span("study.tell"):
            trial_id, state, values = self._normalize_tell(trial, values, state)
            self._storage.set_trial_state_values(trial_id, state, values)
            frozen = self._storage.get_trial(trial_id)
            self.sampler.after_trial(self, frozen, state, values)
            if self._records is not None:
                self._records.refresh()  # ingest the finished trial incrementally

    def tell_batch(
        self,
        results: Sequence[tuple],
        state: TrialState = TrialState.COMPLETE,
    ) -> None:
        """Report many finished trials at once.  Each item is ``(trial,
        values)`` or ``(trial, values, state)``.  Over a batching backend
        (``remote://``) all state transitions travel in one frame."""
        with telemetry.span("study.tell_batch"):
            normalized = []
            for item in results:
                trial, values = item[0], item[1]
                st = item[2] if len(item) > 2 else state
                normalized.append(self._normalize_tell(trial, values, st))
            call_batch = getattr(self._storage, "call_batch", None)
            if call_batch is not None and len(normalized) > 1:
                call_batch(
                    [("set_trial_state_values", (tid, st, vs)) for tid, st, vs in normalized]
                )
                frozens = call_batch([("get_trial", (tid,)) for tid, _, _ in normalized])
            else:
                for tid, st, vs in normalized:
                    self._storage.set_trial_state_values(tid, st, vs)
                frozens = [self._storage.get_trial(tid) for tid, _, _ in normalized]
            for frozen, (tid, st, vs) in zip(frozens, normalized):
                self.sampler.after_trial(self, frozen, st, vs)
            if self._records is not None:
                self._records.refresh()

    @staticmethod
    def _normalize_tell(trial, values, state) -> tuple[int, TrialState, "list[float] | None"]:
        trial_id = trial._trial_id if isinstance(trial, Trial) else int(trial)
        if values is not None:
            values = [float(values)] if not isinstance(values, (list, tuple)) else [
                float(v) for v in values
            ]
        if state == TrialState.COMPLETE and values is None:
            raise ValueError("completed trials need a value")
        if values is not None and any(v != v for v in values):
            state, values = TrialState.FAIL, None  # NaN objective -> failed
        return trial_id, state, values

    def enqueue_trial(self, params: dict[str, Any], user_attrs: dict[str, Any] | None = None) -> None:
        """Seed the study with a known-good configuration (warm start)."""
        t = FrozenTrial(number=-1, state=TrialState.WAITING, system_attrs={"fixed_params": params})
        if user_attrs:
            t.user_attrs.update(user_attrs)
        self._storage.create_new_trial(self._study_id, template_trial=t)

    def stop(self) -> None:
        """Ask ``optimize`` loops in this process to stop after the current trial."""
        self._stop_requested = True

    # -- optimize -------------------------------------------------------------------------

    def optimize(
        self,
        func: ObjectiveFunc,
        n_trials: int | None = None,
        timeout: float | None = None,
        n_jobs: int = 1,
        catch: tuple[type[Exception], ...] = (),
        callbacks: Iterable[Callable[["Study", FrozenTrial], None]] | None = None,
        gc_after_trial: bool = False,
        show_progress_bar: bool = False,
        ask_batch: int = 1,
    ) -> None:
        """``ask_batch > 1`` claims that many trials per storage round trip
        (``ask(n)``) and evaluates them sequentially — the lever distributed
        workers use to amortize remote-storage latency."""
        self._stop_requested = False
        callbacks = list(callbacks or [])
        deadline = time.time() + timeout if timeout is not None else None

        if n_jobs == 1:
            self._optimize_loop(func, n_trials, deadline, catch, callbacks, ask_batch)
            return

        # thread-based parallel trials against shared storage (the in-process
        # version of paper Fig. 7; processes use repro.core.distributed)
        budget_lock = threading.Lock()
        remaining = [n_trials]

        def take() -> bool:
            with budget_lock:
                if remaining[0] is None:
                    return True
                if remaining[0] <= 0:
                    return False
                remaining[0] -= 1
                return True

        def worker():
            while not self._stop_requested:
                if deadline is not None and time.time() > deadline:
                    break
                # grab up to ask_batch budget slots (capped to the sampler's
                # generation size), claim them in one round trip, evaluate
                # sequentially
                eff = max(1, min(ask_batch, self.sampler.joint_wave_size(self, ask_batch)))
                slots = 0
                while slots < eff and take():
                    slots += 1
                if slots == 0:
                    break
                pending = self.ask(slots) if ask_batch > 1 else [None] * slots
                try:
                    while pending:
                        if self._stop_requested or (
                            deadline is not None and time.time() > deadline
                        ):
                            break
                        self._run_one(func, catch, callbacks, trial=pending.pop(0))
                finally:
                    self._release_unrun(pending)

        threads = [threading.Thread(target=worker, daemon=True) for _ in range(n_jobs)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()

    def _optimize_loop(self, func, n_trials, deadline, catch, callbacks, ask_batch=1) -> None:
        i = 0
        pending: list[Trial] = []
        try:
            while n_trials is None or i < n_trials:
                if self._stop_requested:
                    break
                if deadline is not None and time.time() > deadline:
                    break
                if ask_batch > 1 and not pending:
                    want = ask_batch if n_trials is None else min(ask_batch, n_trials - i)
                    # popsize-aware waves: a generation-based sampler (CMA-ES,
                    # NSGA-II) caps the wave so each ask(n) block aligns with
                    # one generation instead of replaying a stale state past it
                    want = max(1, min(want, self.sampler.joint_wave_size(self, want)))
                    pending = self.ask(want)
                trial = pending.pop(0) if pending else None
                self._run_one(func, catch, callbacks, trial=trial)
                i += 1
        finally:
            self._release_unrun(pending)

    def _release_unrun(self, trials: "list[Trial]") -> None:
        """Return batch-asked but never-evaluated trials (stop/deadline/raise)
        to the WAITING queue: no parameter was suggested yet, so enqueued
        warm-start configurations survive and any later ``ask`` — here or on
        another worker — claims them intact instead of leaking RUNNING rows."""
        for t in trials:
            if t is None:
                continue
            try:
                self._storage.set_trial_state_values(t._trial_id, TrialState.WAITING)
            except Exception:
                warnings.warn(f"could not release unevaluated trial {t._trial_id}")

    def _run_one(self, func, catch, callbacks, trial: "Trial | None" = None) -> FrozenTrial:
        if trial is None:
            trial = self.ask()
        trial_id = trial._trial_id

        # fixed params from enqueue_trial
        fixed = self._storage.get_trial(trial_id).system_attrs.get("fixed_params")
        if fixed:
            trial._relative_params = dict(fixed)

        hb_stop = self._start_heartbeat(trial_id)
        state = TrialState.COMPLETE
        values: list[float] | None = None
        try:
            raw = func(trial)
            values = [float(v) for v in raw] if isinstance(raw, (list, tuple)) else [float(raw)]
            if any(v != v for v in values):  # NaN objective -> failed trial
                state, values = TrialState.FAIL, None
                self._storage.set_trial_system_attr(trial_id, "fail:exception", "nan objective")
        except TrialPruned as e:
            state = TrialState.PRUNED
            # record the pruned-at value as the final value when available
            frozen = self._storage.get_trial(trial_id)
            last = frozen.last_step
            if last is not None:
                values = [frozen.intermediate_values[last]]
            self._storage.set_trial_system_attr(trial_id, "pruned:reason", str(e) or "pruned")
        except Exception as e:
            state = TrialState.FAIL
            self._storage.set_trial_system_attr(trial_id, "fail:exception", repr(e))
            if not isinstance(e, catch):
                raise
        finally:
            # exactly one finish on every path — including the uncaught-raise
            # path above, which previously risked finishing the trial twice
            self._finish(trial_id, state, values, hb_stop)

        frozen = self._storage.get_trial(trial_id)
        self.sampler.after_trial(self, frozen, state, values)
        if self._records is not None:
            self._records.refresh()  # keep the columnar store warm
        for cb in callbacks:
            cb(self, frozen)
        return frozen

    def _finish(self, trial_id, state, values, hb_stop) -> None:
        if hb_stop is not None:
            hb_stop.set()
        try:
            self._storage.set_trial_state_values(trial_id, state, values)
        except Exception:
            warnings.warn(f"could not persist final state for trial {trial_id}")

    def _start_heartbeat(self, trial_id: int) -> threading.Event | None:
        if self.heartbeat_interval is None:
            return None
        stop = threading.Event()

        def beat():
            while not stop.wait(self.heartbeat_interval):
                try:
                    self._storage.record_heartbeat(trial_id)
                except Exception:
                    pass

        self._storage.record_heartbeat(trial_id)
        threading.Thread(target=beat, daemon=True).start()
        return stop

    # -- fault tolerance -------------------------------------------------------------------

    def fail_stale_trials(self) -> list[int]:
        """Mark RUNNING trials with expired heartbeats as FAILED; returns their
        trial ids.  Call from any worker (or a janitor) to recover from
        worker crashes."""
        return self._storage.fail_stale_trials(self._study_id, self.failed_trial_grace)

    def retry_failed_trials(self) -> int:
        """Re-enqueue failed trials' parameters (at-least-once execution)."""
        n = 0
        for t in self.get_trials(deepcopy=False, states=(TrialState.FAIL,)):
            if t.system_attrs.get("retried"):
                continue
            self._storage.set_trial_system_attr(t.trial_id, "retried", True)
            self.enqueue_trial(dict(t.params), user_attrs={"retry_of": t.number})
            n += 1
        return n

    # -- export ---------------------------------------------------------------------------

    def trials_dataframe(self) -> list[dict[str, Any]]:
        """Rows of plain dicts (pandas-free analogue of the paper's §4 export;
        feed to ``csv.DictWriter`` or pandas if installed)."""
        rows = []
        for t in self.get_trials(deepcopy=False):
            row: dict[str, Any] = {
                "number": t.number,
                "state": t.state.name,
                "value": t.values[0] if t.values else None,
                "datetime_start": t.datetime_start.isoformat() if t.datetime_start else None,
                "datetime_complete": t.datetime_complete.isoformat() if t.datetime_complete else None,
            }
            if t.values is not None and len(t.values) > 1:
                for k, v in enumerate(t.values):
                    row[f"values_{k}"] = v
            for k, v in t.params.items():
                row[f"params_{k}"] = v
            for k, v in t.user_attrs.items():
                row[f"user_attrs_{k}"] = v
            rows.append(row)
        return rows


def _pairwise_best_trials(
    completed: "list[FrozenTrial]", directions: "list[StudyDirection]"
) -> list[FrozenTrial]:
    """The frozen pre-engine Pareto front: the pure-Python pairwise dominance
    loop ``Study.best_trials`` shipped before the columnar multi-objective
    engine existed.  Kept verbatim as the parity reference —
    ``tests/test_moo.py`` pins the engine bit-identical to this."""
    completed = [
        t for t in completed
        if t.values is not None and len(t.values) == len(directions)
    ]

    def dominates(a: FrozenTrial, b: FrozenTrial) -> bool:
        better = False
        for av, bv, d in zip(a.values, b.values, directions):
            sa = av if d == StudyDirection.MINIMIZE else -av
            sb = bv if d == StudyDirection.MINIMIZE else -bv
            if sa > sb:
                return False
            if sa < sb:
                better = True
        return better

    return [
        t for t in completed if not any(dominates(o, t) for o in completed if o is not t)
    ]


def create_study(
    study_name: str | None = None,
    storage: "str | BaseStorage | None" = None,
    sampler: BaseSampler | None = None,
    pruner: BasePruner | None = None,
    direction: "str | StudyDirection" = "minimize",
    directions: "Sequence[str | StudyDirection] | None" = None,
    load_if_exists: bool = False,
    engine: str = "auto",
) -> Study:
    backend = get_storage(storage)
    if directions is None:
        directions = [direction]
    dirs = [
        d if isinstance(d, StudyDirection) else StudyDirection[d.upper()] for d in directions
    ]
    if study_name is None:
        study_name = f"study-{datetime.datetime.now().strftime('%Y%m%d-%H%M%S-%f')}"
    try:
        backend.create_new_study(dirs, study_name)
    except DuplicatedStudyError:
        if not load_if_exists:
            raise
    return Study(study_name, backend, sampler=sampler, pruner=pruner, engine=engine)


def load_study(
    study_name: str,
    storage: "str | BaseStorage",
    sampler: BaseSampler | None = None,
    pruner: BasePruner | None = None,
    engine: str = "auto",
) -> Study:
    return Study(
        study_name, get_storage(storage), sampler=sampler, pruner=pruner, engine=engine
    )


def delete_study(study_name: str, storage: "str | BaseStorage") -> None:
    backend = get_storage(storage)
    backend.delete_study(backend.get_study_id_from_name(study_name))
