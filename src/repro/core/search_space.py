"""Concurrence-relation inference for relational sampling (paper §3.1).

In a define-by-run framework the search space is only revealed by running
trials.  Relational samplers (CMA-ES, GP) need a *fixed* joint space, so we
infer the **intersection search space**: the set of parameters that occurred
in *every* completed trial so far, with their (latest) distributions.  After
a few independently-sampled trials this recovers the stable joint structure,
and the relational sampler takes over for those parameters while independent
sampling covers the conditional remainder.

Joint-sampling **groups** generalize the intersection: instead of keeping
only the parameters present in *every* trial, :func:`observed_groups`
partitions all observed parameters into connected components of the
co-occurrence relation ("suggested together by at least one trial",
Optuna's ``group=True`` decomposition).  Each group can then be modeled
jointly — one ``BaseSampler.sample_joint`` call per group covers every
pending trial of a batched ``Study.ask(n)`` — while parameters from
different groups never constrain each other.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

from .distributions import BaseDistribution
from .frozen import FrozenTrial, TrialState

if TYPE_CHECKING:
    from .records import ObservationStore
    from .study import Study

__all__ = [
    "intersection_search_space",
    "IntersectionSearchSpace",
    "ParamGroup",
    "observed_groups",
]


@dataclass(frozen=True)
class ParamGroup:
    """One connected component of co-observed parameters.

    ``names`` is sorted; ``dists`` maps each name to the *predicted*
    distribution (the latest one observed in storage).  The prediction is
    what a joint sampler models; a trial whose define-by-run objective
    diverges from it at runtime falls back to scalar sampling (see
    ``Trial._sample``)."""

    names: tuple[str, ...]
    dists: dict[str, BaseDistribution] = field(hash=False)

    def __len__(self) -> int:
        return len(self.names)


def observed_groups(store: "ObservationStore") -> list[ParamGroup]:
    """Group decomposition over a columnar observation store.

    Connected components of the co-occurrence mask (one vectorized boolean
    matmul over the store's dist-type rows, see
    ``ObservationStore.co_occurrence``), joined by union-find.  Parameters
    that were never observed in a COMPLETE/PRUNED trial form no group and
    stay on the per-trial scalar path.  Groups are returned sorted by their
    first parameter name, names sorted within each group."""
    names, mask = store.co_occurrence()
    observed = [i for i in range(len(names)) if mask[i, i]]
    if not observed:
        return []
    parent = list(range(len(names)))

    def find(i: int) -> int:
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    for i in observed:
        for j in mask[i].nonzero()[0]:
            ri, rj = find(i), find(int(j))
            if ri != rj:
                parent[rj] = ri

    components: dict[int, list[str]] = {}
    for i in observed:
        components.setdefault(find(i), []).append(names[i])
    groups = []
    for members in components.values():
        members = sorted(members)
        dists = {n: store.distribution(n) for n in members}
        if any(d is None for d in dists.values()):  # pragma: no cover - racing delete
            continue
        groups.append(ParamGroup(tuple(members), dists))
    return sorted(groups, key=lambda g: g.names[0])


def intersection_search_space(
    trials: list[FrozenTrial], include_pruned: bool = False
) -> dict[str, BaseDistribution]:
    states = (TrialState.COMPLETE, TrialState.PRUNED) if include_pruned else (
        TrialState.COMPLETE,
    )
    space: dict[str, BaseDistribution] | None = None
    for t in trials:
        if t.state not in states:
            continue
        if space is None:
            space = dict(t.distributions)
            continue
        # keep only params present in every trial, with matching dist types
        keep = {}
        for name, dist in space.items():
            other = t.distributions.get(name)
            if other is not None and type(other) is type(dist):
                keep[name] = other  # latest distribution (bounds may drift)
        space = keep
        if not space:
            break
    return dict(sorted((space or {}).items()))


class IntersectionSearchSpace:
    """Incrementally-updated intersection space (avoids re-scanning all trials
    on every ask; important when studies grow to 10^4+ trials).

    Against a real :class:`~repro.core.study.Study` the calculation rides the
    columnar observation store: per parameter, one vector op over the store's
    distribution-type rows decides survival (present in every included trial,
    single type), and the store hands back the latest included distribution —
    no ``FrozenTrial`` materialization at all.  The cursor loop below remains
    as the fallback for duck-typed study objects.
    """

    def __init__(self, include_pruned: bool = False):
        self._cursor = 0
        self._space: dict[str, BaseDistribution] | None = None
        self._include_pruned = include_pruned

    def calculate(self, study: "Study") -> dict[str, BaseDistribution]:
        obs = getattr(study, "observations", None)
        if callable(obs):
            return obs().intersection_space(self._include_pruned)
        states = (TrialState.COMPLETE, TrialState.PRUNED) if self._include_pruned else (
            TrialState.COMPLETE,
        )
        trials = study.get_trials(deepcopy=False, states=None)
        for t in trials[self._cursor:]:
            if not t.state.is_finished():
                # do not advance the cursor past live trials
                break
            self._cursor = t.number + 1
            if t.state not in states:
                continue
            if self._space is None:
                self._space = dict(t.distributions)
                continue
            keep = {}
            for name, dist in self._space.items():
                other = t.distributions.get(name)
                if other is not None and type(other) is type(dist):
                    keep[name] = other
            self._space = keep
        return dict(sorted((self._space or {}).items()))
