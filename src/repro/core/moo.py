"""Multi-objective engine — columnar Pareto/dominance primitives.

Everything multi-objective in the stack funnels through this module:
``Study.best_trials`` / ``Study.pareto_front``, the NSGA-II sampler's
rank+crowding selection, and MOTPE's nondomination split all operate on the
observation store's ``(n_trials, n_objectives)`` values matrix with the
vectorized primitives below, instead of the historical pure-Python pairwise
dominance loop (O(n² · m) interpreter work per call).

Conventions
-----------
* All functions take **loss-oriented** values: every objective is minimized.
  Callers convert maximize objectives by sign (see :func:`loss_matrix`).
* Rows containing NaN follow IEEE comparison semantics: a NaN coordinate is
  neither better nor worse than anything, so it simply contributes no
  evidence either way — exactly what the frozen pairwise loop in ``Study``
  did (its ``dominates`` is ``not any(a > b) and any(a < b)``, and NaN
  comparisons are all False).  Callers that want NaN rows excluded entirely
  mask them out first.

Dominance as a sign-matrix reduction
------------------------------------
``i`` dominates ``j`` iff ``not any(V[i] > V[j])`` and ``any(V[i] < V[j])``
(for NaN-free rows this is the familiar ``all(<=) and any(<)``).
:func:`dominance_matrix` evaluates both reductions for **all** (i, j) pairs
in one broadcasted ``(n, n, m)`` comparison — the multi-objective analogue of
the TPE scorer's one-matrix-op design — with an optional jax path (same
lazy-jit + trace-count policy as the TPE gemm scorer) for the reduction.
Front ranks then fall out of iterated masking over the boolean matrix: peel
the non-dominated rows, drop their domination edges, repeat.
"""

from __future__ import annotations

import logging
from typing import TYPE_CHECKING, Sequence

import numpy as np

from ..kernels import ops as kops
from . import telemetry
from .log import get_logger, log_once

if TYPE_CHECKING:
    from .frozen import StudyDirection

__all__ = [
    "loss_matrix",
    "dominance_matrix",
    "nondomination_ranks",
    "pareto_front_mask",
    "crowding_distance",
    "hypervolume",
    "hypervolume_contributions",
    "HypervolumeEstimator",
    "solve_hssp",
]

_log = get_logger(__name__)

#: rank assigned to rows excluded from the sort (masked out by the caller)
EXCLUDED = -1

_DOM_CHUNK = 256  # rows per broadcasted block: caps the (chunk, n, m) temporary


def loss_matrix(values: np.ndarray, directions: "Sequence[StudyDirection | int]") -> np.ndarray:
    """Orient a raw ``(n, m)`` values matrix so every column is minimized:
    maximize columns are sign-flipped.  Returns a fresh array."""
    V = np.array(values, dtype=float, copy=True)
    if V.ndim != 2 or V.shape[1] != len(directions):
        raise ValueError(
            f"values matrix shape {V.shape} does not match {len(directions)} directions"
        )
    for j, d in enumerate(directions):
        if int(d) == 1:  # StudyDirection.MAXIMIZE
            V[:, j] = -V[:, j]
    return V


# -- dominance ------------------------------------------------------------------

_jax_dominance = None


def _get_jax_dominance():
    """Jitted dominance reduction, built lazily — mirrors the TPE scorer's
    policy: inputs arrive padded to power-of-two row counts so the set of
    shapes XLA ever sees stays logarithmic in the trial count."""
    global _jax_dominance
    if _jax_dominance is None:
        import jax
        import jax.numpy as jnp

        def dom(V):
            kops.bump_trace("moo.dominance")  # body runs once per trace
            # not-any(>) rather than all(<=): identical on NaN-free rows,
            # and matches the pairwise reference's NaN semantics otherwise
            no_worse = ~jnp.any(V[:, None, :] > V[None, :, :], axis=2)
            better = jnp.any(V[:, None, :] < V[None, :, :], axis=2)
            return no_worse & better

        _jax_dominance = jax.jit(dom)
    return _jax_dominance


def _note_engine_fallback(reason: str) -> None:
    telemetry.inc("sampler.engine_fallbacks")
    log_once(
        _log, ("moo-engine-fallback", reason), logging.WARNING,
        "moo device engine downgraded to numpy: %s (logged once; occurrences "
        "counted in sampler.engine_fallbacks)", reason,
    )


def _resolve(engine: "str | None", jit: bool, work: int) -> str:
    """Concrete engine for one dominance-shaped reduction of ``work``
    (= rows x objectives) units.  ``engine=None`` keeps the legacy ``jit``
    switch semantics (False -> numpy, True -> jax); ``"pallas"`` maps to the
    jitted reduction (the comparison cube is XLA-shaped already, there is no
    separate Pallas dominance kernel)."""
    if engine is None:
        engine = "jax" if jit else "numpy"
    if engine == "numpy":
        return "numpy"
    if not kops.jax_available():
        _note_engine_fallback("jax-unavailable")
        return "numpy"
    eng = kops.resolve_engine(
        engine, work, kops.DOM_JIT_THRESHOLD, ceiling=kops.DOM_CPU_CEILING
    )
    return "jax" if eng == "pallas" else eng


def dominance_matrix(
    V: np.ndarray, jit: bool = False, engine: "str | None" = None
) -> np.ndarray:
    """Boolean ``(n, n)`` matrix with ``out[i, j]`` True iff row ``i``
    dominates row ``j`` (loss orientation).  The diagonal is always False
    (a row never strictly improves on itself).

    The numpy path evaluates the two sign-matrix reductions in row chunks so
    the broadcasted ``(chunk, n, m)`` temporaries stay cache-sized; the
    device path runs the whole reduction as one jitted kernel with
    power-of-two padding (padding rows are +inf: they dominate nothing and
    are sliced off before return).  ``engine`` follows the shared policy
    (``"auto"`` picks the device past ``DOM_JIT_THRESHOLD`` rows x
    objectives, up to ``DOM_CPU_CEILING`` off-TPU — the reduction
    materializes the (n, n, m) cube); the legacy ``jit`` flag is equivalent
    to ``engine="jax"``.
    """
    V = np.asarray(V, dtype=float)
    n = len(V)
    if n == 0:
        return np.zeros((0, 0), dtype=bool)
    if _resolve(engine, jit, n * V.shape[1]) == "jax":
        try:
            size = kops.pad_pow2_len(n)
            if size != n:
                P = np.full((size, V.shape[1]), np.inf)
                P[:n] = V
            else:
                P = V
            return np.asarray(_get_jax_dominance()(P))[:n, :n]
        except Exception as e:  # device dispatch failed: downgrade loudly
            _note_engine_fallback(f"dominance-device-error:{type(e).__name__}")
    out = np.empty((n, n), dtype=bool)
    m = V.shape[1]
    with np.errstate(invalid="ignore"):
        for start in range(0, n, _DOM_CHUNK):
            stop = min(start + _DOM_CHUNK, n)
            # unrolled over objectives (m is tiny): each pass is one full-speed
            # contiguous (chunk, n) comparison — an order of magnitude faster
            # than broadcasting a (chunk, n, m) cube and reducing its last axis
            any_gt = np.zeros((stop - start, n), dtype=bool)
            any_lt = np.zeros((stop - start, n), dtype=bool)
            scratch = np.empty((stop - start, n), dtype=bool)
            for k in range(m):
                b = V[start:stop, k][:, None]
                c = V[:, k][None, :]
                np.greater(b, c, out=scratch)
                np.logical_or(any_gt, scratch, out=any_gt)
                np.less(b, c, out=scratch)
                np.logical_or(any_lt, scratch, out=any_lt)
            np.logical_not(any_gt, out=any_gt)
            np.logical_and(any_gt, any_lt, out=out[start:stop])
    return out


def nondomination_ranks(
    V: np.ndarray,
    mask: "np.ndarray | None" = None,
    jit: bool = False,
    engine: "str | None" = None,
) -> np.ndarray:
    """Front rank per row (0 = Pareto front) via iterated masking over the
    dominance matrix: rows not dominated by any active row form the current
    front, are assigned the rank, and drop out of the active set.

    ``mask`` (optional) excludes rows from the sort entirely — they get rank
    :data:`EXCLUDED` and constrain nothing.  NaN rows that *are* included end
    up on front 0 (IEEE semantics, matching the pairwise reference)."""
    V = np.asarray(V, dtype=float)
    n = len(V)
    ranks = np.full(n, EXCLUDED, dtype=np.int64)
    active = np.ones(n, dtype=bool) if mask is None else np.asarray(mask, dtype=bool).copy()
    if not active.any():
        return ranks
    idx = np.flatnonzero(active)
    dom = dominance_matrix(V[idx], jit=jit, engine=engine)
    # dominated_by[j] = number of active rows dominating j; peel fronts by
    # subtracting the peeled rows' edges instead of re-reducing the matrix
    dominated_by = dom.sum(axis=0).astype(np.int64)
    remaining = np.ones(len(idx), dtype=bool)
    rank = 0
    while remaining.any():
        front = remaining & (dominated_by == 0)
        if not front.any():  # pragma: no cover - cycles are impossible
            front = remaining
        ranks[idx[front]] = rank
        remaining &= ~front
        dominated_by -= dom[front].sum(axis=0)
        rank += 1
    return ranks


_PREFILTER_MIN = 512   # below this a single dominance reduction is cheaper
_PREFILTER_PICKS = 64  # strong-dominator candidates used to thin the field


def _dominated_by_any(V: np.ndarray, D: np.ndarray) -> np.ndarray:
    """``out[i]`` True iff some row of ``D`` dominates ``V[i]`` — evaluated
    per objective like :func:`dominance_matrix`, (n, len(D)) at a time."""
    n, m = V.shape
    any_gt = np.zeros((n, len(D)), dtype=bool)
    any_lt = np.zeros((n, len(D)), dtype=bool)
    scratch = np.empty((n, len(D)), dtype=bool)
    for k in range(m):
        v = V[:, k][:, None]
        d = D[:, k][None, :]
        np.less(d, v, out=scratch)      # dominator strictly better somewhere
        np.logical_or(any_lt, scratch, out=any_lt)
        np.greater(d, v, out=scratch)   # dominator worse somewhere -> no dom
        np.logical_or(any_gt, scratch, out=any_gt)
    return (~any_gt & any_lt).any(axis=1)


def pareto_front_mask(
    V: np.ndarray,
    mask: "np.ndarray | None" = None,
    jit: bool = False,
    engine: "str | None" = None,
) -> np.ndarray:
    """Boolean mask of the non-dominated rows (front 0), without peeling the
    remaining fronts.

    NaN-free inputs above :data:`_PREFILTER_MIN` rows take a two-stage path:
    a handful of strong dominators (smallest objective sums) eliminate the
    bulk of the field in O(n · picks · m), and the full dominance reduction
    runs only on the survivors.  This is exact because NaN-free dominance is
    transitive — a row dominated by an eliminated row is also dominated by
    whatever eliminated it, so survivors-vs-survivors decides the front.
    NaN rows break transitivity (a NaN coordinate is incomparable either
    way), so any NaN input falls back to the single full reduction, keeping
    bit-parity with the pairwise reference."""
    V = np.asarray(V, dtype=float)
    n = len(V)
    out = np.zeros(n, dtype=bool)
    active = np.ones(n, dtype=bool) if mask is None else np.asarray(mask, dtype=bool)
    idx = np.flatnonzero(active)
    if len(idx) == 0:
        return out
    A = V[idx]
    if len(idx) >= _PREFILTER_MIN and not np.isnan(A).any():
        finite = np.where(np.isfinite(A), A, np.inf)
        # normalize per objective so no single scale dominates the pick
        lo = finite.min(axis=0)
        span = np.where(finite.max(axis=0) > lo, finite.max(axis=0) - lo, 1.0)
        with np.errstate(invalid="ignore"):
            score = ((finite - lo) / span).sum(axis=1)
        picks = A[np.argsort(score, kind="stable")[:_PREFILTER_PICKS]]
        survivors = np.flatnonzero(~_dominated_by_any(A, picks))
        S = A[survivors]
        dom = dominance_matrix(S, jit=jit, engine=engine)
        out[idx[survivors]] = ~dom.any(axis=0)
        return out
    dom = dominance_matrix(A, jit=jit, engine=engine)
    out[idx] = ~dom.any(axis=0)
    return out


# -- crowding distance ----------------------------------------------------------

def crowding_distance(V: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance of each row *within the given set* (callers
    pass one front at a time).  Boundary rows per objective get +inf;
    interior rows sum their normalized neighbour gaps.  Vectorized: one
    argsort per objective, no Python loop over rows."""
    V = np.asarray(V, dtype=float)
    n, m = V.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for j in range(m):
        col = V[:, j]
        order = np.argsort(col, kind="stable")
        sorted_col = col[order]
        span = sorted_col[-1] - sorted_col[0]
        gaps = np.empty(n)
        gaps[0] = gaps[-1] = np.inf
        if span > 0 and np.isfinite(span):
            gaps[1:-1] = (sorted_col[2:] - sorted_col[:-2]) / span
        else:
            gaps[1:-1] = 0.0
        dist[order] += gaps
    return dist


# -- hypervolume ----------------------------------------------------------------

def hypervolume(points: np.ndarray, reference: np.ndarray) -> float:
    """Exact hypervolume dominated by ``points`` w.r.t. ``reference`` (loss
    orientation: a point counts iff it is <= the reference in every
    objective).  2-D uses a sorted sweep; higher dimensions run the WFG
    exclusive-volume recursion (While et al., 2012) over the non-dominated
    set — exact for any m, intended for m <= 4 where front sizes keep the
    recursion shallow."""
    points = np.asarray(points, dtype=float)
    reference = np.asarray(reference, dtype=float)
    if points.ndim != 2 or points.shape[1] != len(reference):
        raise ValueError(f"points shape {points.shape} vs reference {reference.shape}")
    # clip to the reference box: points outside contribute only their inside part
    keep = (points <= reference).all(axis=1)
    points = points[keep]
    if len(points) == 0:
        return 0.0
    points = points[pareto_front_mask(points)]
    return float(_wfg(points, reference))


def _wfg(points: np.ndarray, ref: np.ndarray) -> float:
    m = points.shape[1]
    if m == 1:
        return float(ref[0] - points.min())
    if m == 2:
        return _hv2d(points, ref)
    # WFG: sort (heuristically, by first objective) and sum exclusive volumes
    order = np.argsort(points[:, 0], kind="stable")
    points = points[order]
    total = 0.0
    for i in range(len(points)):
        p = points[i]
        rest = points[i + 1:]
        incl = float(np.prod(ref - p))
        if len(rest) == 0:
            total += incl
            continue
        limited = np.maximum(rest, p)            # limit set w.r.t. p
        limited = limited[pareto_front_mask(limited)]
        total += incl - _wfg(limited, ref)
    return total


def _hv2d(points: np.ndarray, ref: np.ndarray) -> float:
    """2-D hypervolume by a single sweep over the front sorted by the first
    objective (the front is already mutually non-dominated, so the second
    objective is strictly decreasing along the sweep)."""
    order = np.lexsort((points[:, 1], points[:, 0]))
    pts = points[order]
    total = 0.0
    prev_y = ref[1]
    for x, y in pts:
        if y < prev_y:
            total += (ref[0] - x) * (prev_y - y)
            prev_y = y
    return float(total)


def hypervolume_contributions(
    points: np.ndarray,
    reference: np.ndarray,
    estimator: "HypervolumeEstimator | None" = None,
) -> np.ndarray:
    """Per-point exclusive hypervolume: ``hv(all) - hv(all minus point)``.
    The MOTPE below-set weights (Ozaki et al., 2020) are these contributions
    normalized to [0, 1].  With an ``estimator`` the call routes through its
    method policy (exact leave-one-out for small m, one Monte-Carlo counting
    pass for many objectives)."""
    if estimator is not None:
        return estimator.contributions(points, reference)
    points = np.asarray(points, dtype=float)
    n = len(points)
    if n == 0:
        return np.zeros(0)
    if n == 1:
        return np.asarray([hypervolume(points, reference)])
    total = hypervolume(points, reference)
    out = np.empty(n)
    keep = np.ones(n, dtype=bool)
    for i in range(n):
        keep[i] = False
        out[i] = total - hypervolume(points[keep], reference)
        keep[i] = True
    return out


# -- Monte-Carlo hypervolume ------------------------------------------------------

_jax_mc_counts = None


def _get_jax_mc_counts():
    """Jitted MC domination counting — the plain-jit sibling of the Pallas
    ``mc_hv_counts`` kernel (one broadcasted [s, n, m] cube instead of
    streamed sample tiles)."""
    global _jax_mc_counts
    if _jax_mc_counts is None:
        import jax
        import jax.numpy as jnp

        def counts(pts, smp):
            kops.bump_trace("moo.mc_hv")  # body runs once per trace
            dom = jnp.all(pts[None, :, :] <= smp[:, None, :], axis=2)
            cnt = dom.sum(axis=1)
            excl = (dom & (cnt == 1)[:, None]).sum(axis=0).astype(jnp.float32)
            total = (cnt > 0).sum().astype(jnp.float32)
            return excl, total

        _jax_mc_counts = jax.jit(counts)
    return _jax_mc_counts


def _mc_counts_numpy(
    pts: np.ndarray, samples: np.ndarray
) -> tuple[np.ndarray, float]:
    """Chunked host-side domination counting (the parity reference)."""
    excl = np.zeros(len(pts))
    total = 0.0
    for start in range(0, len(samples), 4096):
        smp = samples[start:start + 4096]
        dom = np.all(pts[None, :, :] <= smp[:, None, :], axis=2)
        cnt = dom.sum(axis=1)
        total += float((cnt > 0).sum())
        excl += (dom & (cnt == 1)[:, None]).sum(axis=0)
    return excl, total


class HypervolumeEstimator:
    """Hypervolume / per-point contribution estimator with a method policy.

    The exact WFG recursion is exponential in the objective count: past
    m = 4 front sizes make it intractable, which historically capped MOTPE
    at few-objective studies.  ``method="auto"`` keeps the exact recursion
    where it is cheap (m <= 4) and switches to Monte-Carlo counting above:
    ``n_samples`` points drawn uniformly in the bounding box
    ``[min(points), reference]``, hypervolume estimated from the dominated
    fraction and per-point contributions from the *exclusively* dominated
    fraction (samples covered by exactly one point — in expectation exactly
    ``hv(all) - hv(all minus point)``).  Standard error scales as
    ``box_volume / sqrt(n_samples)`` independent of m.

    The counting pass dispatches through the shared engine policy: numpy
    below ``DOM_JIT_THRESHOLD`` units of work (points x samples), the jitted
    reduction or the Pallas streaming kernel above it.  The sample draw is
    seeded, so repeated calls on one front are deterministic."""

    def __init__(
        self,
        method: str = "auto",
        n_samples: int = 8192,
        seed: int = 0,
        engine: str = "auto",
    ) -> None:
        if method not in ("auto", "exact", "mc"):
            raise ValueError(f"method must be auto|exact|mc, got {method!r}")
        self._method = method
        self._n_samples = int(n_samples)
        self._seed = int(seed)
        self._engine = kops.validate_engine(engine)

    def _use_exact(self, m: int) -> bool:
        if self._method == "exact":
            return True
        if self._method == "mc":
            return False
        return m <= 4

    def hypervolume(self, points: np.ndarray, reference: np.ndarray) -> float:
        points = np.asarray(points, dtype=float)
        reference = np.asarray(reference, dtype=float)
        if self._use_exact(points.shape[1] if points.ndim == 2 else len(reference)):
            return hypervolume(points, reference)
        keep = (points <= reference).all(axis=1)
        pts = points[keep]
        if len(pts) == 0:
            return 0.0
        hv, _ = self._mc_stats(pts, reference)
        return hv

    def contributions(self, points: np.ndarray, reference: np.ndarray) -> np.ndarray:
        points = np.asarray(points, dtype=float)
        reference = np.asarray(reference, dtype=float)
        if self._use_exact(points.shape[1] if points.ndim == 2 else len(reference)):
            return hypervolume_contributions(points, reference)
        n = len(points)
        out = np.zeros(n)
        keep = (points <= reference).all(axis=1)
        pts = points[keep]
        if len(pts) == 0:
            # outside-the-box points contribute nothing, same as the exact
            # path where hv(all minus point) == hv(all)
            return out
        _, contrib = self._mc_stats(pts, reference)
        out[keep] = contrib
        return out

    def _mc_stats(
        self, pts: np.ndarray, reference: np.ndarray
    ) -> tuple[float, np.ndarray]:
        """``(hv_estimate, per-point contribution estimates)`` for points
        already clipped inside the reference box."""
        lo = pts.min(axis=0)
        box = float(np.prod(reference - lo))
        if not np.isfinite(box) or box <= 0.0:
            return 0.0, np.zeros(len(pts))
        rng = np.random.RandomState(self._seed)
        samples = rng.uniform(lo, reference, size=(self._n_samples, pts.shape[1]))
        excl, total = self._counts(pts, samples)
        scale = box / self._n_samples
        return float(total) * scale, np.asarray(excl, dtype=float) * scale

    def _counts(
        self, pts: np.ndarray, samples: np.ndarray
    ) -> tuple[np.ndarray, float]:
        eng = self._engine
        if eng != "numpy":
            if not kops.jax_available():
                _note_engine_fallback("jax-unavailable")
                eng = "numpy"
            else:
                eng = kops.resolve_engine(
                    eng, len(pts) * len(samples), kops.DOM_JIT_THRESHOLD
                )
        if eng != "numpy":
            try:
                n = len(pts)
                if eng == "pallas":
                    excl, total = kops.mc_hv_counts_op(pts, samples)
                else:
                    # pad point rows to pow2 with +inf (dominate nothing) so
                    # XLA retraces O(log n) times; sample count is fixed
                    P = kops.pad_pow2_rows(np.asarray(pts, dtype=np.float32), np.inf)
                    excl, total = _get_jax_mc_counts()(P, samples.astype(np.float32))
                return np.asarray(excl)[:n], float(total)
            except Exception as e:  # device dispatch failed: downgrade loudly
                _note_engine_fallback(f"mc-hv-device-error:{type(e).__name__}")
        return _mc_counts_numpy(pts, samples)


def solve_hssp(
    points: np.ndarray,
    k: int,
    reference: np.ndarray,
    estimator: "HypervolumeEstimator | None" = None,
) -> np.ndarray:
    """Greedy hypervolume subset selection: pick ``k`` of ``points``
    approximately maximizing the joint hypervolume (the 1-1/e greedy of
    Guerreiro et al.).  Returns the selected row indices in pick order.
    MOTPE uses it to break ties on the boundary nondomination rank.  With an
    ``estimator`` every subset evaluation routes through its method policy,
    keeping the greedy tractable for many objectives."""
    points = np.asarray(points, dtype=float)
    n = len(points)
    k = min(int(k), n)
    if k <= 0:
        return np.zeros(0, dtype=np.int64)
    hv = (
        (lambda P: estimator.hypervolume(P, reference))
        if estimator is not None
        else (lambda P: hypervolume(P, reference))
    )
    contrib = np.asarray([hv(points[i:i + 1]) for i in range(n)])
    selected: list[int] = []
    selected_rows: list[np.ndarray] = []
    hv_selected = 0.0
    picked = np.zeros(n, dtype=bool)
    while len(selected) < k:
        i = int(np.argmax(np.where(picked, -np.inf, contrib)))
        picked[i] = True
        selected.append(i)
        if len(selected) == k:
            break
        # discount every remaining candidate by the volume it shares with the
        # newly picked point, relative to the set selected *before* the pick
        for j in range(n):
            if picked[j]:
                continue
            joined = np.maximum(points[j], points[i])
            contrib[j] -= hv(np.asarray(selected_rows + [joined])) - hv_selected
        selected_rows.append(points[i])
        hv_selected = hv(np.asarray(selected_rows))
    return np.asarray(selected, dtype=np.int64)


def default_reference_point(points: np.ndarray) -> np.ndarray:
    """MOTPE's reference-point heuristic: 1.1x the worst observed value per
    objective (0.9x for negative coordinates, epsilon for exact zeros)."""
    worst = np.max(points, axis=0)
    ref = np.maximum(1.1 * worst, 0.9 * worst)
    ref[ref == 0] = 1e-12
    return ref
