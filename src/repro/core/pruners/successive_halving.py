"""Asynchronous Successive Halving — the paper's Algorithm 1, vectorized.

    Input: target trial `trial`, current step `step`, minimum resource r,
           reduction factor eta, minimum early-stopping rate s.
    Output: true if the trial should be pruned.

    1  rung <- max(0, log_eta(floor(step / r)) - s)
    2  if step != r * eta^(s+rung) then return false
    5  value <- get_trial_intermediate_value(trial, step)
    6  values <- get_all_trials_intermediate_values(step)
    7  top_k_values <- top_k(values, floor(|values| / eta))
    8  if top_k_values = empty then top_k_values <- top_k(values, 1)
    11 return value not in top_k_values

Line 6 is one column slice of the intermediate-value store (the exact-step
column, masked by state), and lines 7-11 reduce to an ``np.partition`` for
the k-th best — no sort, no per-trial dict walk.  The frozen scalar twin in
``pruners/_legacy.py`` anchors the bit-identical parity suite.

Properties the tests pin down:

* **asynchronous** — a worker decides from whatever peer values exist *now*;
  it never waits for a rung cohort to fill (linear scaling, paper §5.3).
  Peer semantics (pinned by ``tests/test_pruners.py``): the peer set
  includes **RUNNING** trials (plus COMPLETE and PRUNED) — ASHA ranks
  against in-flight reports by design, unlike
  :class:`~.median.PercentilePruner`, whose peers are COMPLETE only.
* **no repechage** — a pruned trial is never resumed, so no snapshots of
  model state need to be stored (paper §3.2).
* when fewer than eta trials reached a rung, the best one is still promoted
  (line 8-10).
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

import numpy as np

from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BasePruner, study_iv_store

if TYPE_CHECKING:
    from ..records import IntermediateValueStore
    from ..study import Study

__all__ = ["SuccessiveHalvingPruner"]


class SuccessiveHalvingPruner(BasePruner):
    def __init__(
        self,
        min_resource: int = 1,
        reduction_factor: int = 4,
        min_early_stopping_rate: int = 0,
    ):
        if min_resource < 1:
            raise ValueError("min_resource must be >= 1")
        if reduction_factor < 2:
            raise ValueError("reduction_factor must be >= 2")
        if min_early_stopping_rate < 0:
            raise ValueError("min_early_stopping_rate must be >= 0")
        self._r = min_resource
        self._eta = reduction_factor
        self._s = min_early_stopping_rate

    def spec(self) -> "dict | None":
        if not self._fusable(SuccessiveHalvingPruner):
            return None
        return {
            "name": "successive_halving",
            "min_resource": self._r,
            "reduction_factor": self._eta,
            "min_early_stopping_rate": self._s,
        }

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        store = study_iv_store(study)
        if store is None:  # duck-typed study: scalar fallback
            from ._legacy import LegacySuccessiveHalvingPruner

            return LegacySuccessiveHalvingPruner(
                self._r, self._eta, self._s
            ).prune(study, trial)
        return self.decide(study.direction, store, trial)

    def decide(
        self, direction: StudyDirection, store: "IntermediateValueStore",
        trial: FrozenTrial,
    ) -> bool:
        return self._decide_masked(direction, store, trial, None)

    def _decide_masked(
        self, direction: StudyDirection, store: "IntermediateValueStore",
        trial: FrozenTrial, peer_mask: "np.ndarray | None",
    ) -> bool:
        """Algorithm 1 with an optional extra row mask (Hyperband restricts
        peers to the trial's bracket this way — no study-view indirection)."""
        step = trial.last_step
        if step is None:
            return False

        r, eta, s = self._r, self._eta, self._s

        # line 1: rung <- max(0, log_eta(floor(step/r)) - s)
        if step < r:
            return False
        rung = max(0, int(math.log(step // r, eta)) - s)

        # line 2: only act exactly at rung boundaries step == r * eta^(s+rung)
        if step != r * eta ** (s + rung):
            return False

        value = trial.intermediate_values[step]
        if value != value:  # NaN never survives a rung
            return True

        # line 6: all peer values at this step — one masked column slice
        with store.lock():
            col_vals = store.step_column(step)
            if col_vals is None:
                peer_vals = np.empty(0)
            else:
                states = store.states
                mask = (
                    (states == int(TrialState.COMPLETE))
                    | (states == int(TrialState.PRUNED))
                    | (states == int(TrialState.RUNNING))
                ) & (store.trial_ids != trial.trial_id) & ~np.isnan(col_vals)
                if peer_mask is not None:
                    mask &= peer_mask
                peer_vals = col_vals[mask]
        all_values = np.append(peer_vals, value)

        # lines 7-10: keep top floor(n/eta); if that's empty, keep the single
        # best — the k-th best is one np.partition, no full sort
        k = len(all_values) // eta
        if k == 0:
            k = 1
        if direction == StudyDirection.MINIMIZE:
            kth = np.partition(all_values, k - 1)[k - 1]
            return not value <= kth
        kth = np.partition(all_values, len(all_values) - k)[len(all_values) - k]
        return not value >= kth
