"""Asynchronous Successive Halving — the paper's Algorithm 1, verbatim.

    Input: target trial `trial`, current step `step`, minimum resource r,
           reduction factor eta, minimum early-stopping rate s.
    Output: true if the trial should be pruned.

    1  rung <- max(0, log_eta(floor(step / r)) - s)
    2  if step != r * eta^(s+rung) then return false
    5  value <- get_trial_intermediate_value(trial, step)
    6  values <- get_all_trials_intermediate_values(step)
    7  top_k_values <- top_k(values, floor(|values| / eta))
    8  if top_k_values = empty then top_k_values <- top_k(values, 1)
    11 return value not in top_k_values

Properties the tests pin down:

* **asynchronous** — a worker decides from whatever peer values exist *now*;
  it never waits for a rung cohort to fill (linear scaling, paper §5.3).
* **no repechage** — a pruned trial is never resumed, so no snapshots of
  model state need to be stored (paper §3.2).
* when fewer than eta trials reached a rung, the best one is still promoted
  (line 8-10).
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BasePruner

if TYPE_CHECKING:
    from ..study import Study

__all__ = ["SuccessiveHalvingPruner"]


class SuccessiveHalvingPruner(BasePruner):
    def __init__(
        self,
        min_resource: int = 1,
        reduction_factor: int = 4,
        min_early_stopping_rate: int = 0,
    ):
        if min_resource < 1:
            raise ValueError("min_resource must be >= 1")
        if reduction_factor < 2:
            raise ValueError("reduction_factor must be >= 2")
        if min_early_stopping_rate < 0:
            raise ValueError("min_early_stopping_rate must be >= 0")
        self._r = min_resource
        self._eta = reduction_factor
        self._s = min_early_stopping_rate

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        step = trial.last_step
        if step is None:
            return False

        r, eta, s = self._r, self._eta, self._s

        # line 1: rung <- max(0, log_eta(floor(step/r)) - s)
        if step < r:
            return False
        rung = max(0, int(math.log(step // r, eta)) - s)

        # line 2: only act exactly at rung boundaries step == r * eta^(s+rung)
        if step != r * eta ** (s + rung):
            return False

        value = trial.intermediate_values[step]
        if value != value:  # NaN never survives a rung
            return True

        # line 6: all peer intermediate values at this step
        all_values = []
        for t in study.get_trials(deepcopy=False):
            if t.trial_id == trial.trial_id:
                continue
            if t.state in (TrialState.COMPLETE, TrialState.PRUNED, TrialState.RUNNING):
                v = t.intermediate_values.get(step)
                if v is not None and v == v:
                    all_values.append(v)
        all_values.append(value)

        # lines 7-10: keep top floor(n/eta); if that's empty, keep the single best
        k = len(all_values) // eta
        if k == 0:
            k = 1
        if study.direction == StudyDirection.MINIMIZE:
            top_k = sorted(all_values)[:k]
            return not value <= top_k[-1]
        else:
            top_k = sorted(all_values, reverse=True)[:k]
            return not value >= top_k[-1]
