"""Small auxiliary pruners: threshold and patience wrappers.

Both judge only the target trial's own reported values (no peer scan), so
their ``decide`` implementations are trial-local — they still participate in
the fused ``report_and_prune`` round trip via ``spec()``, and
:class:`PatientPruner` forwards the store to whatever pruner it wraps.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

from ..frozen import FrozenTrial, StudyDirection
from .base import BasePruner

if TYPE_CHECKING:
    from ..records import IntermediateValueStore
    from ..study import Study

__all__ = ["ThresholdPruner", "PatientPruner"]


class ThresholdPruner(BasePruner):
    """Prune when an intermediate value leaves [lower, upper] (divergence
    guard: NaN/inf or loss explosion kills the trial immediately)."""

    def __init__(
        self,
        lower: float | None = None,
        upper: float | None = None,
        n_warmup_steps: int = 0,
    ):
        if lower is None and upper is None:
            raise ValueError("give at least one of lower/upper")
        self._lower = lower
        self._upper = upper
        self._warmup = n_warmup_steps

    def spec(self) -> "dict | None":
        if not self._fusable(ThresholdPruner):
            return None
        return {
            "name": "threshold",
            "lower": self._lower,
            "upper": self._upper,
            "n_warmup_steps": self._warmup,
        }

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        return self._evaluate(trial)

    def decide(self, direction, store, trial) -> bool:
        return self._evaluate(trial)

    def _evaluate(self, trial: FrozenTrial) -> bool:
        step = trial.last_step
        if step is None or step < self._warmup:
            return False
        v = trial.intermediate_values[step]
        if v != v or math.isinf(v):
            return True
        if self._lower is not None and v < self._lower:
            return True
        if self._upper is not None and v > self._upper:
            return True
        return False


class PatientPruner(BasePruner):
    """Wraps another pruner; only lets it fire after the trial has made no
    improvement for ``patience`` consecutive reports."""

    def __init__(self, wrapped: BasePruner | None, patience: int, min_delta: float = 0.0):
        if patience < 0 or min_delta < 0:
            raise ValueError("invalid patience/min_delta")
        self._wrapped = wrapped
        self._patience = patience
        self._min_delta = min_delta

    def spec(self) -> "dict | None":
        if not self._fusable(PatientPruner):
            return None
        wrapped_spec = self._wrapped.spec() if self._wrapped is not None else None
        if self._wrapped is not None and wrapped_spec is None:
            return None  # wrapped pruner cannot cross the wire -> no fusion
        return {
            "name": "patient",
            "patience": self._patience,
            "min_delta": self._min_delta,
            "wrapped": wrapped_spec,
        }

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        if not self._stalled(trial, study.direction):
            return False
        if self._wrapped is None:
            return True
        return self._wrapped.prune(study, trial)

    def decide(
        self, direction: StudyDirection, store: "IntermediateValueStore",
        trial: FrozenTrial,
    ) -> bool:
        if not self._stalled(trial, direction):
            return False
        if self._wrapped is None:
            return True
        return self._wrapped.decide(direction, store, trial)

    def _stalled(self, trial: FrozenTrial, direction: StudyDirection) -> bool:
        ivs = trial.intermediate_values
        if len(ivs) <= self._patience:
            return False
        steps = sorted(ivs)
        vals = [ivs[s] for s in steps]
        minimize = direction == StudyDirection.MINIMIZE
        window = vals[-(self._patience + 1):]
        if minimize:
            improved = min(window[1:]) < window[0] - self._min_delta
        else:
            improved = max(window[1:]) > window[0] + self._min_delta
        return not improved
