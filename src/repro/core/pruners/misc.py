"""Small auxiliary pruners: threshold and patience wrappers."""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

from ..frozen import FrozenTrial, StudyDirection
from .base import BasePruner

if TYPE_CHECKING:
    from ..study import Study

__all__ = ["ThresholdPruner", "PatientPruner"]


class ThresholdPruner(BasePruner):
    """Prune when an intermediate value leaves [lower, upper] (divergence
    guard: NaN/inf or loss explosion kills the trial immediately)."""

    def __init__(
        self,
        lower: float | None = None,
        upper: float | None = None,
        n_warmup_steps: int = 0,
    ):
        if lower is None and upper is None:
            raise ValueError("give at least one of lower/upper")
        self._lower = lower
        self._upper = upper
        self._warmup = n_warmup_steps

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        step = trial.last_step
        if step is None or step < self._warmup:
            return False
        v = trial.intermediate_values[step]
        if v != v or math.isinf(v):
            return True
        if self._lower is not None and v < self._lower:
            return True
        if self._upper is not None and v > self._upper:
            return True
        return False


class PatientPruner(BasePruner):
    """Wraps another pruner; only lets it fire after the trial has made no
    improvement for ``patience`` consecutive reports."""

    def __init__(self, wrapped: BasePruner | None, patience: int, min_delta: float = 0.0):
        if patience < 0 or min_delta < 0:
            raise ValueError("invalid patience/min_delta")
        self._wrapped = wrapped
        self._patience = patience
        self._min_delta = min_delta

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        ivs = trial.intermediate_values
        if len(ivs) <= self._patience:
            return False
        steps = sorted(ivs)
        vals = [ivs[s] for s in steps]
        minimize = study.direction == StudyDirection.MINIMIZE
        window = vals[-(self._patience + 1):]
        if minimize:
            improved = min(window[1:]) < window[0] - self._min_delta
        else:
            improved = max(window[1:]) > window[0] + self._min_delta
        if improved:
            return False
        if self._wrapped is None:
            return True
        return self._wrapped.prune(study, trial)
