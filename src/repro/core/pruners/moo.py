"""Pareto-aware pruning: scalarize multi-objective reports onto the fused path.

Multi-objective studies historically skipped fusion entirely — pruning is a
single-objective concept, so ``Trial.report`` fell back to a bare
``set_trial_intermediate_value`` and ``should_prune`` was a client-side no-op
(ROADMAP PR-3 follow-up).  :class:`ParetoPruner` closes that gap without
teaching the wire format about vectors of intermediate values:

* the worker reports a **vector** of per-objective intermediate values;
* the pruner scalarizes it client-side with the augmented Chebyshev
  (reference-point) function — a standard Pareto-compliant scalarization:
  if one vector dominates another, its scalarized value is strictly smaller,
  so ranking scalarized curves never promotes a dominated trial;
* the scalar rides the **existing** fused ``report_and_prune`` storage op
  (one round trip, server-side peer data, spec interning — everything PR-3/4
  built), with the wrapped single-objective pruner deciding on the
  scalarized stream under an always-MINIMIZE direction.

The scalarized values are what lands in storage (and therefore in the
intermediate-value store's matrix): one consistent stream that every
vectorized pruner can rank, at the cost of not persisting per-objective
learning curves — callers that need those record them as user attrs.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Sequence

from ..frozen import FrozenTrial, StudyDirection
from .base import BasePruner

if TYPE_CHECKING:
    from ..records import IntermediateValueStore
    from ..study import Study

__all__ = ["ParetoPruner"]


class ParetoPruner(BasePruner):
    """Wraps a single-objective pruner for multi-objective studies.

    Args:
        wrapped: the pruner judging the scalarized stream (any fusable
            built-in: median/percentile/sha/hyperband/threshold/patient...).
        reference_point: per-objective aspiration levels in **raw study
            orientation** (defaults to all zeros).  Values are oriented to
            minimize-losses before the reference point is subtracted.
        weights: per-objective scalarization weights (default uniform).
        rho: augmentation factor of the Chebyshev term — ``0`` gives the pure
            weighted max, small positive values break ties toward vectors
            better on the remaining objectives.
    """

    def __init__(
        self,
        wrapped: BasePruner,
        reference_point: "Sequence[float] | None" = None,
        weights: "Sequence[float] | None" = None,
        rho: float = 0.05,
    ):
        if wrapped is None:
            raise ValueError("ParetoPruner needs a wrapped single-objective pruner")
        if rho < 0:
            raise ValueError("rho must be >= 0")
        self._wrapped = wrapped
        self._reference = list(reference_point) if reference_point is not None else None
        self._weights = list(weights) if weights is not None else None
        self._rho = float(rho)

    # -- scalarization (the hook Trial.report dispatches on) --------------------

    def scalarize(self, values: Sequence[float], directions: Sequence[StudyDirection]) -> float:
        """Augmented Chebyshev value of one report vector: ``max_k w_k (l_k -
        r_k) + rho * sum_k w_k (l_k - r_k)`` over minimize-oriented losses
        ``l``.  Strictly monotone in every objective, so dominance order is
        preserved on the scalarized stream."""
        m = len(directions)
        if len(values) != m:
            raise ValueError(
                f"report carries {len(values)} values for {m} study directions"
            )
        ref = self._reference if self._reference is not None else [0.0] * m
        w = self._weights if self._weights is not None else [1.0 / m] * m
        if len(ref) != m or len(w) != m:
            raise ValueError("reference_point/weights arity does not match directions")
        terms = []
        for v, d, r, wk in zip(values, directions, ref, w):
            loss = float(v) if d == StudyDirection.MINIMIZE else -float(v)
            terms.append(wk * (loss - r))
        return max(terms) + self._rho * sum(terms)

    # -- pruner interface --------------------------------------------------------

    def spec(self) -> "dict | None":
        if not self._fusable(ParetoPruner):
            return None
        wrapped_spec = self._wrapped.spec()
        if wrapped_spec is None:
            return None  # wrapped pruner cannot cross the wire -> no fusion
        return {
            "name": "pareto",
            "wrapped": wrapped_spec,
            "reference_point": self._reference,
            "weights": self._weights,
            "rho": self._rho,
        }

    def decide(
        self, direction: StudyDirection, store: "IntermediateValueStore",
        trial: FrozenTrial,
    ) -> bool:
        # the stored stream is already scalarized to a loss: the wrapped
        # pruner always judges it as MINIMIZE, whatever the study directions
        return self._wrapped.decide(StudyDirection.MINIMIZE, store, trial)

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        from .base import study_iv_store

        store = study_iv_store(study)
        if store is None:  # pragma: no cover - duck-typed study
            return False
        return self._wrapped.decide(StudyDirection.MINIMIZE, store, trial)
