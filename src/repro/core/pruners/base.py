from __future__ import annotations

from typing import TYPE_CHECKING

from ..frozen import FrozenTrial

if TYPE_CHECKING:
    from ..study import Study

__all__ = ["BasePruner", "NopPruner"]


class BasePruner:
    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        """Return True iff ``trial`` should be stopped now, judging from its
        reported intermediate values and the study history (paper Fig. 5)."""
        raise NotImplementedError


class NopPruner(BasePruner):
    """Never prunes (the paper's 'no pruning' baseline in Fig. 11a)."""

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        return False
