from __future__ import annotations

from typing import TYPE_CHECKING

from ..frozen import FrozenTrial, StudyDirection

if TYPE_CHECKING:
    from ..records import IntermediateValueStore
    from ..study import Study

__all__ = ["BasePruner", "NopPruner"]


class BasePruner:
    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        """Return True iff ``trial`` should be stopped now, judging from its
        reported intermediate values and the study history (paper Fig. 5)."""
        raise NotImplementedError

    def decide(
        self, direction: StudyDirection, store: "IntermediateValueStore",
        trial: FrozenTrial,
    ) -> bool:
        """Vectorized decision against an intermediate-value store.

        Peer data comes from ``store`` (already refreshed by the caller);
        the target trial's own reported values come from ``trial`` — its row
        in the store is always excluded, so a value fresher than the store's
        snapshot still decides correctly.  Both ``prune`` (client side,
        through ``Study.intermediate_values()``) and the fused
        ``report_and_prune`` storage op (server side, against the backend's
        own store) funnel into this method.
        """
        raise NotImplementedError

    def spec(self) -> "dict | None":
        """JSON-serializable description of this pruner for the fused
        ``report_and_prune`` wire format (see ``pruner_from_spec``).  ``None``
        disables fusion: ``Trial.report`` falls back to a plain
        ``set_trial_intermediate_value`` and ``should_prune`` evaluates the
        pruner client-side."""
        return None

    def _fusable(self, *exact_types: type) -> bool:
        """Built-in ``spec()`` implementations guard on this: a user subclass
        (which may override ``prune``/``decide``) must NOT ship the parent's
        spec — the deciding side would rebuild the plain built-in and
        silently bypass the override — so fusion is limited to the exact
        built-in classes and subclasses fall back to client-side
        evaluation."""
        return type(self) in exact_types


def study_iv_store(study) -> "IntermediateValueStore | None":
    """The study's intermediate-value store (refreshed), or None for
    duck-typed study objects that do not expose one — vectorized pruners
    then fall back to their frozen scalar twins."""
    getter = getattr(study, "intermediate_values", None)
    return getter() if callable(getter) else None


class NopPruner(BasePruner):
    """Never prunes (the paper's 'no pruning' baseline in Fig. 11a)."""

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        return False

    def decide(self, direction, store, trial) -> bool:
        return False

    def spec(self) -> "dict | None":
        # shipping the nop spec lets report+should_prune collapse to the one
        # fused round trip too (backends short-circuit it after the write)
        return {"name": "nop"} if self._fusable(NopPruner) else None
