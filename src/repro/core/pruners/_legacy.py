"""Frozen pre-refactor scalar pruner implementations (the PR-2-era path).

These are verbatim copies of the pruners as they existed before the
intermediate-value backbone landed: every ``prune`` call re-walks all trials'
``intermediate_values`` dicts in pure Python — O(n_trials x n_steps)
interpreter work per reported step.

They exist for two purposes only:

* the **decision-parity suite** (``tests/test_pruner_parity.py``) asserts
  the vectorized pruners produce bit-identical prune decisions, and
* the **prune-decision benchmark** (``benchmarks/pruning.py --prune-bench``)
  measures the speedup of the columnar path against this baseline.

One deliberate deviation from the verbatim freeze: the RUNNING-peer
inconsistency fix (PercentilePruner peers are COMPLETE trials only, matching
Optuna) is applied here too, so parity compares vectorization — not the
semantics change, which lands in both stacks.  See ``median.py``.

Do not modify and do not use in new code.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

import numpy as np

from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BasePruner

if TYPE_CHECKING:
    from ..study import Study

__all__ = [
    "LegacyPercentilePruner",
    "LegacyMedianPruner",
    "LegacySuccessiveHalvingPruner",
    "LegacyHyperbandPruner",
    "LegacyThresholdPruner",
    "LegacyPatientPruner",
]


class LegacyPercentilePruner(BasePruner):
    """Prune if the trial's best-so-far intermediate value is worse than the
    given percentile of peer best-so-far values at the same step."""

    def __init__(
        self,
        percentile: float,
        n_startup_trials: int = 5,
        n_warmup_steps: int = 0,
        interval_steps: int = 1,
    ):
        if not 0.0 <= percentile <= 100.0:
            raise ValueError("percentile must be in [0, 100]")
        if n_startup_trials < 0 or n_warmup_steps < 0 or interval_steps < 1:
            raise ValueError("invalid pruner configuration")
        self._q = percentile
        self._n_startup = n_startup_trials
        self._warmup = n_warmup_steps
        self._interval = interval_steps

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        step = trial.last_step
        if step is None or step < self._warmup:
            return False
        if (step - self._warmup) % self._interval != 0:
            return False

        minimize = study.direction == StudyDirection.MINIMIZE

        def best_until(t: FrozenTrial, upto: int) -> float | None:
            vals = [v for s, v in t.intermediate_values.items() if s <= upto and v == v]
            if not vals:
                return None
            return min(vals) if minimize else max(vals)

        peers = []
        for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,)):
            if t.trial_id == trial.trial_id:
                continue
            b = best_until(t, step)
            if b is not None:
                peers.append(b)
        if len(peers) < self._n_startup:
            return False

        mine = best_until(trial, step)
        if mine is None:
            return False
        if mine != mine:  # NaN
            return True
        cutoff = float(np.percentile(peers, self._q if minimize else 100.0 - self._q))
        return mine > cutoff if minimize else mine < cutoff


class LegacyMedianPruner(LegacyPercentilePruner):
    def __init__(
        self, n_startup_trials: int = 5, n_warmup_steps: int = 0, interval_steps: int = 1
    ):
        super().__init__(50.0, n_startup_trials, n_warmup_steps, interval_steps)


class LegacySuccessiveHalvingPruner(BasePruner):
    """The paper's Algorithm 1, scalar (see ``successive_halving.py``)."""

    def __init__(
        self,
        min_resource: int = 1,
        reduction_factor: int = 4,
        min_early_stopping_rate: int = 0,
    ):
        if min_resource < 1:
            raise ValueError("min_resource must be >= 1")
        if reduction_factor < 2:
            raise ValueError("reduction_factor must be >= 2")
        if min_early_stopping_rate < 0:
            raise ValueError("min_early_stopping_rate must be >= 0")
        self._r = min_resource
        self._eta = reduction_factor
        self._s = min_early_stopping_rate

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        step = trial.last_step
        if step is None:
            return False

        r, eta, s = self._r, self._eta, self._s

        # line 1: rung <- max(0, log_eta(floor(step/r)) - s)
        if step < r:
            return False
        rung = max(0, int(math.log(step // r, eta)) - s)

        # line 2: only act exactly at rung boundaries step == r * eta^(s+rung)
        if step != r * eta ** (s + rung):
            return False

        value = trial.intermediate_values[step]
        if value != value:  # NaN never survives a rung
            return True

        # line 6: all peer intermediate values at this step
        all_values = []
        for t in study.get_trials(deepcopy=False):
            if t.trial_id == trial.trial_id:
                continue
            if t.state in (TrialState.COMPLETE, TrialState.PRUNED, TrialState.RUNNING):
                v = t.intermediate_values.get(step)
                if v is not None and v == v:
                    all_values.append(v)
        all_values.append(value)

        # lines 7-10: keep top floor(n/eta); if that's empty, keep the single best
        k = len(all_values) // eta
        if k == 0:
            k = 1
        if study.direction == StudyDirection.MINIMIZE:
            top_k = sorted(all_values)[:k]
            return not value <= top_k[-1]
        else:
            top_k = sorted(all_values, reverse=True)[:k]
            return not value >= top_k[-1]


class LegacyHyperbandPruner(BasePruner):
    def __init__(
        self,
        min_resource: int = 1,
        max_resource: int = 64,
        reduction_factor: int = 4,
    ):
        self._r = min_resource
        self._R = max_resource
        self._eta = reduction_factor
        n_brackets = int(math.log(max(self._R // self._r, 1), self._eta)) + 1
        self._pruners = [
            LegacySuccessiveHalvingPruner(
                min_resource=min_resource,
                reduction_factor=reduction_factor,
                min_early_stopping_rate=s,
            )
            for s in range(n_brackets)
        ]
        weights = [self._eta**s / (s + 1) for s in range(n_brackets)]
        total = sum(weights)
        self._cum = []
        acc = 0.0
        for w in weights:
            acc += w / total
            self._cum.append(acc)

    @property
    def n_brackets(self) -> int:
        return len(self._pruners)

    def bracket_of(self, trial: FrozenTrial) -> int:
        h = (trial.number * 2654435761) % (2**32) / 2**32
        for i, c in enumerate(self._cum):
            if h <= c:
                return i
        return len(self._cum) - 1

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        bracket = self.bracket_of(trial)
        view = _LegacyBracketView(study, self, bracket)
        return self._pruners[bracket].prune(view, trial)


class _LegacyBracketView:
    """A study view that filters trials to one bracket so SHA ranks only
    within-bracket peers."""

    def __init__(self, study: "Study", hb: LegacyHyperbandPruner, bracket: int):
        self._study = study
        self._hb = hb
        self._bracket = bracket

    @property
    def direction(self):
        return self._study.direction

    def get_trials(self, deepcopy: bool = False, states=None):
        return [
            t
            for t in self._study.get_trials(deepcopy=deepcopy, states=states)
            if self._hb.bracket_of(t) == self._bracket
        ]


class LegacyThresholdPruner(BasePruner):
    def __init__(
        self,
        lower: float | None = None,
        upper: float | None = None,
        n_warmup_steps: int = 0,
    ):
        if lower is None and upper is None:
            raise ValueError("give at least one of lower/upper")
        self._lower = lower
        self._upper = upper
        self._warmup = n_warmup_steps

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        step = trial.last_step
        if step is None or step < self._warmup:
            return False
        v = trial.intermediate_values[step]
        if v != v or math.isinf(v):
            return True
        if self._lower is not None and v < self._lower:
            return True
        if self._upper is not None and v > self._upper:
            return True
        return False


class LegacyPatientPruner(BasePruner):
    def __init__(self, wrapped: BasePruner | None, patience: int, min_delta: float = 0.0):
        if patience < 0 or min_delta < 0:
            raise ValueError("invalid patience/min_delta")
        self._wrapped = wrapped
        self._patience = patience
        self._min_delta = min_delta

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        ivs = trial.intermediate_values
        if len(ivs) <= self._patience:
            return False
        steps = sorted(ivs)
        vals = [ivs[s] for s in steps]
        minimize = study.direction == StudyDirection.MINIMIZE
        window = vals[-(self._patience + 1):]
        if minimize:
            improved = min(window[1:]) < window[0] - self._min_delta
        else:
            improved = max(window[1:]) > window[0] + self._min_delta
        if improved:
            return False
        if self._wrapped is None:
            return True
        return self._wrapped.prune(study, trial)
