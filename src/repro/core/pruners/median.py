"""Median / percentile pruning — the Vizier-style baseline of Fig. 11a."""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

import numpy as np

from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BasePruner

if TYPE_CHECKING:
    from ..study import Study

__all__ = ["MedianPruner", "PercentilePruner"]


class PercentilePruner(BasePruner):
    """Prune if the trial's best-so-far intermediate value is worse than the
    given percentile of peer best-so-far values at the same step."""

    def __init__(
        self,
        percentile: float,
        n_startup_trials: int = 5,
        n_warmup_steps: int = 0,
        interval_steps: int = 1,
    ):
        if not 0.0 <= percentile <= 100.0:
            raise ValueError("percentile must be in [0, 100]")
        if n_startup_trials < 0 or n_warmup_steps < 0 or interval_steps < 1:
            raise ValueError("invalid pruner configuration")
        self._q = percentile
        self._n_startup = n_startup_trials
        self._warmup = n_warmup_steps
        self._interval = interval_steps

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        step = trial.last_step
        if step is None or step < self._warmup:
            return False
        if (step - self._warmup) % self._interval != 0:
            return False

        minimize = study.direction == StudyDirection.MINIMIZE

        def best_until(t: FrozenTrial, upto: int) -> float | None:
            vals = [v for s, v in t.intermediate_values.items() if s <= upto and v == v]
            if not vals:
                return None
            return min(vals) if minimize else max(vals)

        peers = []
        for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE, TrialState.PRUNED)):
            if t.trial_id == trial.trial_id:
                continue
            b = best_until(t, step)
            if b is not None:
                peers.append(b)
        if len(peers) < self._n_startup:
            return False

        mine = best_until(trial, step)
        if mine is None:
            return False
        if mine != mine:  # NaN
            return True
        cutoff = float(np.percentile(peers, self._q if minimize else 100.0 - self._q))
        return mine > cutoff if minimize else mine < cutoff


class MedianPruner(PercentilePruner):
    """PercentilePruner at the median (the pruner Vizier features; paper
    Fig. 11a shows ASHA dominating it)."""

    def __init__(
        self, n_startup_trials: int = 5, n_warmup_steps: int = 0, interval_steps: int = 1
    ):
        super().__init__(50.0, n_startup_trials, n_warmup_steps, interval_steps)
