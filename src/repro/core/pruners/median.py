"""Median / percentile pruning — the Vizier-style baseline of Fig. 11a.

Vectorized: one decision is a column slice of the intermediate-value store's
cached best-so-far matrix plus one ``np.percentile`` — O(n_trials) numpy work
instead of a Python re-walk of every peer's ``intermediate_values`` dict
(the frozen scalar twin lives in ``pruners/_legacy.py``; the parity suite
asserts bit-identical decisions).
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BasePruner, study_iv_store

if TYPE_CHECKING:
    from ..records import IntermediateValueStore
    from ..study import Study

__all__ = ["MedianPruner", "PercentilePruner"]


def _best_until(trial: FrozenTrial, upto: int, minimize: bool) -> "float | None":
    vals = [v for s, v in trial.intermediate_values.items() if s <= upto and v == v]
    if not vals:
        return None
    return min(vals) if minimize else max(vals)


class PercentilePruner(BasePruner):
    """Prune if the trial's best-so-far intermediate value is worse than the
    given percentile of peer best-so-far values at the same step.

    Peer semantics (pinned by ``tests/test_pruners.py``): the peer set is
    **COMPLETE trials only** — RUNNING and PRUNED trials are excluded,
    matching Optuna's percentile/median pruners.  Contrast with
    :class:`~.successive_halving.SuccessiveHalvingPruner`, which by ASHA's
    asynchronous design ranks against RUNNING (and PRUNED) peers as well.
    """

    def __init__(
        self,
        percentile: float,
        n_startup_trials: int = 5,
        n_warmup_steps: int = 0,
        interval_steps: int = 1,
    ):
        if not 0.0 <= percentile <= 100.0:
            raise ValueError("percentile must be in [0, 100]")
        if n_startup_trials < 0 or n_warmup_steps < 0 or interval_steps < 1:
            raise ValueError("invalid pruner configuration")
        self._q = percentile
        self._n_startup = n_startup_trials
        self._warmup = n_warmup_steps
        self._interval = interval_steps

    def spec(self) -> "dict | None":
        if not self._fusable(PercentilePruner, MedianPruner):
            return None
        return {
            "name": "percentile",
            "percentile": self._q,
            "n_startup_trials": self._n_startup,
            "n_warmup_steps": self._warmup,
            "interval_steps": self._interval,
        }

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        store = study_iv_store(study)
        if store is None:  # duck-typed study: scalar fallback
            from ._legacy import LegacyPercentilePruner

            return LegacyPercentilePruner(
                self._q, self._n_startup, self._warmup, self._interval
            ).prune(study, trial)
        return self.decide(study.direction, store, trial)

    def decide(
        self, direction: StudyDirection, store: "IntermediateValueStore",
        trial: FrozenTrial,
    ) -> bool:
        step = trial.last_step
        if step is None or step < self._warmup:
            return False
        if (step - self._warmup) % self._interval != 0:
            return False

        minimize = direction == StudyDirection.MINIMIZE
        with store.lock():
            col = store.index_upto(step)
            if col < 0:
                peers = np.empty(0)
            else:
                bsf = store.best_so_far(minimize)[:, col]
                mask = (store.states == int(TrialState.COMPLETE)) & (
                    store.trial_ids != trial.trial_id
                )
                peers = bsf[mask]
                peers = peers[~np.isnan(peers)]
        if len(peers) < self._n_startup:
            return False

        mine = _best_until(trial, step, minimize)
        if mine is None:
            return False
        if mine != mine:  # NaN
            return True
        cutoff = float(np.percentile(peers, self._q if minimize else 100.0 - self._q))
        return mine > cutoff if minimize else mine < cutoff


class MedianPruner(PercentilePruner):
    """PercentilePruner at the median (the pruner Vizier features; paper
    Fig. 11a shows ASHA dominating it)."""

    def __init__(
        self, n_startup_trials: int = 5, n_warmup_steps: int = 0, interval_steps: int = 1
    ):
        super().__init__(50.0, n_startup_trials, n_warmup_steps, interval_steps)
