"""Hyperband over ASHA brackets (beyond-paper; Li et al. 2018).

Hyperband hedges SHA's fixed aggressiveness by running several SHA brackets
with different minimum early-stopping rates ``s``.  Each trial is hashed into
a bracket (deterministic in trial number, so distributed workers agree without
coordination), and within a bracket the paper's Algorithm 1 applies.
Bracket sizes follow the standard Hyperband budget allocation.

Vectorized: bracket assignment is one hashed vector op over the store's row
numbers (Knuth multiplicative hash + ``searchsorted`` into the cumulative
bracket weights), producing the peer mask the bracket's SHA decision applies
— the old per-trial study-view filter re-hashed every trial per decision.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

import numpy as np

from ..frozen import FrozenTrial, StudyDirection
from .base import BasePruner, study_iv_store
from .successive_halving import SuccessiveHalvingPruner

if TYPE_CHECKING:
    from ..records import IntermediateValueStore
    from ..study import Study

__all__ = ["HyperbandPruner"]


class HyperbandPruner(BasePruner):
    def __init__(
        self,
        min_resource: int = 1,
        max_resource: int = 64,
        reduction_factor: int = 4,
    ):
        self._r = min_resource
        self._R = max_resource
        self._eta = reduction_factor
        n_brackets = int(math.log(max(self._R // self._r, 1), self._eta)) + 1
        self._pruners = [
            SuccessiveHalvingPruner(
                min_resource=min_resource,
                reduction_factor=reduction_factor,
                min_early_stopping_rate=s,
            )
            for s in range(n_brackets)
        ]
        # standard hyperband allocation: bracket s gets weight ~ (eta^s)/(s+1)
        weights = [self._eta**s / (s + 1) for s in range(n_brackets)]
        total = sum(weights)
        self._cum = []
        acc = 0.0
        for w in weights:
            acc += w / total
            self._cum.append(acc)
        self._cum_arr = np.asarray(self._cum)

    @property
    def n_brackets(self) -> int:
        return len(self._pruners)

    def spec(self) -> "dict | None":
        if not self._fusable(HyperbandPruner):
            return None
        return {
            "name": "hyperband",
            "min_resource": self._r,
            "max_resource": self._R,
            "reduction_factor": self._eta,
        }

    def bracket_of(self, trial: FrozenTrial) -> int:
        return int(self.brackets_of(np.asarray([trial.number]))[0])

    def brackets_of(self, numbers: np.ndarray) -> np.ndarray:
        """Deterministic, coordination-free bracket assignment, batched:
        h = (number * 2654435761) mod 2^32 / 2^32, first cumulative weight
        >= h wins."""
        h = (numbers.astype(np.int64) * 2654435761) % (2**32) / 2**32
        idx = np.searchsorted(self._cum_arr, h, side="left")
        return np.minimum(idx, len(self._cum) - 1)

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        store = study_iv_store(study)
        if store is None:  # duck-typed study: scalar fallback
            from ._legacy import LegacyHyperbandPruner

            return LegacyHyperbandPruner(self._r, self._R, self._eta).prune(
                study, trial
            )
        return self.decide(study.direction, store, trial)

    def decide(
        self, direction: StudyDirection, store: "IntermediateValueStore",
        trial: FrozenTrial,
    ) -> bool:
        bracket = self.bracket_of(trial)
        # hold the store lock across mask construction *and* the SHA decision
        # (reentrant), so a concurrent refresh cannot grow the rows between
        # the two and misalign the bracket mask
        with store.lock():
            peer_mask = self.brackets_of(np.arange(store.n_rows)) == bracket
            return self._pruners[bracket]._decide_masked(
                direction, store, trial, peer_mask
            )
