"""Hyperband over ASHA brackets (beyond-paper; Li et al. 2018).

Hyperband hedges SHA's fixed aggressiveness by running several SHA brackets
with different minimum early-stopping rates ``s``.  Each trial is hashed into
a bracket (deterministic in trial number, so distributed workers agree without
coordination), and within a bracket the paper's Algorithm 1 applies.
Bracket sizes follow the standard Hyperband budget allocation.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

from ..frozen import FrozenTrial, TrialState
from .base import BasePruner
from .successive_halving import SuccessiveHalvingPruner

if TYPE_CHECKING:
    from ..study import Study

__all__ = ["HyperbandPruner"]


class HyperbandPruner(BasePruner):
    def __init__(
        self,
        min_resource: int = 1,
        max_resource: int = 64,
        reduction_factor: int = 4,
    ):
        self._r = min_resource
        self._R = max_resource
        self._eta = reduction_factor
        n_brackets = int(math.log(max(self._R // self._r, 1), self._eta)) + 1
        self._pruners = [
            SuccessiveHalvingPruner(
                min_resource=min_resource,
                reduction_factor=reduction_factor,
                min_early_stopping_rate=s,
            )
            for s in range(n_brackets)
        ]
        # standard hyperband allocation: bracket s gets weight ~ (eta^s)/(s+1)
        weights = [self._eta**s / (s + 1) for s in range(n_brackets)]
        total = sum(weights)
        self._cum = []
        acc = 0.0
        for w in weights:
            acc += w / total
            self._cum.append(acc)

    @property
    def n_brackets(self) -> int:
        return len(self._pruners)

    def bracket_of(self, trial: FrozenTrial) -> int:
        # deterministic, coordination-free bracket assignment
        h = (trial.number * 2654435761) % (2**32) / 2**32
        for i, c in enumerate(self._cum):
            if h <= c:
                return i
        return len(self._cum) - 1

    def prune(self, study: "Study", trial: FrozenTrial) -> bool:
        bracket = self.bracket_of(trial)
        view = _BracketView(study, self, bracket)
        return self._pruners[bracket].prune(view, trial)


class _BracketView:
    """A study view that filters trials to one bracket so SHA ranks only
    within-bracket peers."""

    def __init__(self, study: "Study", hb: HyperbandPruner, bracket: int):
        self._study = study
        self._hb = hb
        self._bracket = bracket

    @property
    def direction(self):
        return self._study.direction

    def get_trials(self, deepcopy: bool = False, states=None):
        return [
            t
            for t in self._study.get_trials(deepcopy=deepcopy, states=states)
            if self._hb.bracket_of(t) == self._bracket
        ]
