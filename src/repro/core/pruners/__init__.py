from __future__ import annotations

from .base import BasePruner, NopPruner
from .hyperband import HyperbandPruner
from .median import MedianPruner, PercentilePruner
from .misc import PatientPruner, ThresholdPruner
from .successive_halving import SuccessiveHalvingPruner

__all__ = [
    "BasePruner",
    "NopPruner",
    "SuccessiveHalvingPruner",
    "MedianPruner",
    "PercentilePruner",
    "HyperbandPruner",
    "ThresholdPruner",
    "PatientPruner",
    "make_pruner",
]


def make_pruner(name: str, **kwargs) -> BasePruner:
    name = name.lower()
    if name in ("none", "nop"):
        return NopPruner()
    if name in ("asha", "sha", "successive_halving"):
        return SuccessiveHalvingPruner(**kwargs)
    if name == "median":
        return MedianPruner(**kwargs)
    if name == "hyperband":
        return HyperbandPruner(**kwargs)
    if name == "percentile":
        return PercentilePruner(**kwargs)
    if name == "threshold":
        return ThresholdPruner(**kwargs)
    raise ValueError(f"unknown pruner {name!r}")
