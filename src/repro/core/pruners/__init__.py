from __future__ import annotations

from .base import BasePruner, NopPruner
from .hyperband import HyperbandPruner
from .median import MedianPruner, PercentilePruner
from .misc import PatientPruner, ThresholdPruner
from .moo import ParetoPruner
from .successive_halving import SuccessiveHalvingPruner

__all__ = [
    "BasePruner",
    "NopPruner",
    "SuccessiveHalvingPruner",
    "MedianPruner",
    "PercentilePruner",
    "HyperbandPruner",
    "ThresholdPruner",
    "PatientPruner",
    "ParetoPruner",
    "make_pruner",
    "pruner_from_spec",
]


def make_pruner(name: str, **kwargs) -> BasePruner:
    name = name.lower()
    if name in ("none", "nop"):
        return NopPruner()
    if name in ("asha", "sha", "successive_halving"):
        return SuccessiveHalvingPruner(**kwargs)
    if name == "median":
        return MedianPruner(**kwargs)
    if name == "hyperband":
        return HyperbandPruner(**kwargs)
    if name == "percentile":
        return PercentilePruner(**kwargs)
    if name == "threshold":
        return ThresholdPruner(**kwargs)
    raise ValueError(f"unknown pruner {name!r}")


def pruner_from_spec(spec: dict) -> BasePruner:
    """Rebuild a pruner from its ``BasePruner.spec()`` wire form.

    This is the server side of the fused ``report_and_prune`` storage op:
    the worker ships ``{"name": ..., **constructor_kwargs}``, the backend
    reconstructs the pruner and evaluates its vectorized ``decide`` against
    its own intermediate-value store.  Specs are tiny and pruners are cheap
    to build, so no instance caching is needed.
    """
    if not isinstance(spec, dict) or "name" not in spec:
        raise ValueError(f"malformed pruner spec: {spec!r}")
    kwargs = {k: v for k, v in spec.items() if k != "name"}
    if spec["name"] == "patient":
        wrapped = kwargs.pop("wrapped", None)
        return PatientPruner(
            pruner_from_spec(wrapped) if wrapped is not None else None, **kwargs
        )
    if spec["name"] == "pareto":
        wrapped = kwargs.pop("wrapped", None)
        if wrapped is None:
            raise ValueError("pareto spec needs a wrapped pruner spec")
        return ParetoPruner(pruner_from_spec(wrapped), **kwargs)
    return make_pruner(spec["name"], **kwargs)
