"""The live ``Trial`` object — the paper's central abstraction.

An objective function receives a *living trial object* and constructs the
search space dynamically by calling the suggest API (paper §2, Fig. 1):

    def objective(trial):
        n_layers = trial.suggest_int("n_layers", 1, 4)
        for i in range(n_layers):
            ...

``FixedTrial`` replays a fixed parameter set through the same objective for
deployment (paper §2.2).
"""

from __future__ import annotations

import datetime
import math
from typing import TYPE_CHECKING, Any, Sequence

from .distributions import (
    BaseDistribution,
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
)
from .exceptions import TrialPruned
from .frozen import FrozenTrial, StudyDirection, TrialState, iv_vec_key

if TYPE_CHECKING:
    from .study import Study

__all__ = ["Trial", "FixedTrial"]


class BaseTrial:
    """Shared suggest API between live and fixed trials."""

    # subclasses implement _suggest(name, distribution) -> external value

    def suggest_float(
        self,
        name: str,
        low: float,
        high: float,
        *,
        log: bool = False,
        step: float | None = None,
    ) -> float:
        return self._suggest(name, FloatDistribution(low, high, log=log, step=step))

    def suggest_int(
        self, name: str, low: int, high: int, *, log: bool = False, step: int = 1
    ) -> int:
        return self._suggest(name, IntDistribution(low, high, log=log, step=step))

    def suggest_categorical(self, name: str, choices: Sequence[Any]) -> Any:
        return self._suggest(name, CategoricalDistribution(choices))

    # legacy aliases (paper-era API)
    def suggest_uniform(self, name: str, low: float, high: float) -> float:
        return self.suggest_float(name, low, high)

    def suggest_loguniform(self, name: str, low: float, high: float) -> float:
        return self.suggest_float(name, low, high, log=True)

    def suggest_discrete_uniform(self, name: str, low: float, high: float, q: float) -> float:
        return self.suggest_float(name, low, high, step=q)

    def _suggest(self, name: str, distribution: BaseDistribution) -> Any:
        raise NotImplementedError

    def report(self, value: float, step: int) -> None:
        raise NotImplementedError

    def should_prune(self) -> bool:
        raise NotImplementedError


class Trial(BaseTrial):
    """A live trial bound to a study + storage.

    Every ``suggest_*`` call (1) checks whether this parameter was already
    suggested in this trial (idempotent re-suggest returns the same value),
    (2) otherwise asks the study's sampler for a value conditioned on trial
    history, and (3) persists (value, distribution) to storage so *other
    workers'* samplers see it immediately.
    """

    def __init__(self, study: "Study", trial_id: int):
        self.study = study
        self._trial_id = trial_id
        self._cached: FrozenTrial | None = None
        # relative (relational) sampling happens once, lazily, at first suggest
        self._relative_params: dict[str, Any] | None = None
        # joint block slice: {name: model-space value} presampled by a batched
        # ``Study.ask(n)`` (see Study._presample_joint); None on the scalar
        # path.  When set, suggest calls slice it instead of sampling, and
        # the per-trial relational stage is skipped (the block replaced it).
        self._joint: "dict[str, float] | None" = None
        self._joint_dists: "dict[str, BaseDistribution]" = {}
        # fused report→prune: decision for the last reported step, if any
        self._prune_decision: "tuple[int, bool] | None" = None
        self._last_report: "tuple[int, float] | None" = None

    # -- identity -------------------------------------------------------------

    @property
    def number(self) -> int:
        return self._frozen().number

    @property
    def params(self) -> dict[str, Any]:
        return dict(self._frozen(refresh=True).params)

    @property
    def distributions(self) -> dict[str, BaseDistribution]:
        return dict(self._frozen(refresh=True).distributions)

    @property
    def user_attrs(self) -> dict[str, Any]:
        return dict(self._frozen(refresh=True).user_attrs)

    @property
    def system_attrs(self) -> dict[str, Any]:
        return dict(self._frozen(refresh=True).system_attrs)

    @property
    def datetime_start(self) -> datetime.datetime | None:
        return self._frozen().datetime_start

    def _frozen(self, refresh: bool = False) -> FrozenTrial:
        if self._cached is None or refresh:
            self._cached = self.study._storage.get_trial(self._trial_id)
        return self._cached

    # -- suggest ---------------------------------------------------------------

    def _suggest(self, name: str, distribution: BaseDistribution) -> Any:
        storage = self.study._storage
        frozen = self._frozen(refresh=True)
        if name in frozen.distributions:
            # idempotent re-suggest within a trial
            from .distributions import check_distribution_compatibility

            check_distribution_compatibility(frozen.distributions[name], distribution)
            return frozen.params[name]

        if distribution.single():
            # domain of size one: no sampling needed
            internal = distribution.to_internal_repr(
                distribution.to_external_repr(
                    distribution.low if hasattr(distribution, "low") else 0.0
                )
            )
        else:
            internal = self._sample(name, distribution, frozen)

        storage.set_trial_param(self._trial_id, name, internal, distribution)
        self._cached = None
        return distribution.to_external_repr(internal)

    def _sample(self, name: str, distribution: BaseDistribution, frozen: FrozenTrial) -> float:
        sampler = self.study.sampler
        if self._relative_params is None and self._joint is None:
            # infer the concurrence relations once per trial (paper §3.1) and
            # run the relational sampler over them.  Joint-presampled trials
            # skip this stage entirely: the block already played the
            # relational role for the whole wave (re-running it would e.g.
            # claim a second grid cell).
            space = sampler.infer_relative_search_space(self.study, frozen)
            self._relative_params = sampler.sample_relative(self.study, frozen, space)
        if self._relative_params and name in self._relative_params:
            ext = self._relative_params[name]
            if distribution._contains(distribution.to_internal_repr(ext)):
                return distribution.to_internal_repr(ext)
        joint = self._joint_value(name, distribution)
        if joint is not None:
            return joint
        return distribution.to_internal_repr(
            sampler.sample_independent(self.study, frozen, name, distribution)
        )

    def _joint_value(self, name: str, distribution: BaseDistribution) -> "float | None":
        """Slice the presampled joint block for one suggest call.

        Returns the internal-repr value when the block covers ``name`` and
        the runtime distribution still matches the group prediction;
        otherwise None, falling back to scalar sampling.  Divergences
        (dynamic search-space branches, drifted bounds, changed types) are
        reported once per study — not per trial — via
        ``Study._note_joint_miss``."""
        if self._joint is None:
            return None
        model = self._joint.get(name)
        if model is None:
            # the group prediction never saw this parameter: a dynamic
            # define-by-run branch the history did not cover
            self.study._note_joint_miss(name, "not in any observed group")
            return None
        if math.isnan(model):
            return None  # sampler declined this column by design; silent
        predicted = self._joint_dists.get(name)
        if predicted is None or type(predicted) is not type(distribution) or (
            isinstance(distribution, CategoricalDistribution) and predicted != distribution
        ):
            self.study._note_joint_miss(name, "distribution type changed")
            return None
        if getattr(predicted, "log", False) != getattr(distribution, "log", False):
            # same type but a different coordinate system: the block value is
            # a log-space (resp. linear) number the runtime codec would
            # silently misread as linear (resp. log)
            self.study._note_joint_miss(name, "log flag changed")
            return None
        # containment must be checked in *model space* against the runtime
        # domain: from_internal clips into bounds, so a post-clip _contains
        # test could never detect a drifted domain
        low, high = distribution.internal_bounds(expand_int=True)
        if not (low <= model <= high):
            self.study._note_joint_miss(name, "bounds drifted past the block")
            return None
        return float(distribution.from_internal([model])[0])

    # -- pruning interface (paper Fig. 5) ---------------------------------------

    def report(self, value: "float | Sequence[float]", step: int) -> None:
        """Report an intermediate objective value at ``step`` ('report API').

        When the study's pruner ships a wire spec (every built-in does), the
        report rides the fused ``report_and_prune`` storage op: the value is
        persisted *and* the prune decision comes back on the same round trip
        — server-side peer data over ``remote://`` — so the following
        ``should_prune()`` answers from the cached decision with zero extra
        storage calls.

        On multi-objective studies ``value`` may be a **vector** (one entry
        per study direction).  A Pareto-aware pruner
        (:class:`~repro.core.pruners.ParetoPruner`) scalarizes it client-side
        into a minimize-oriented loss, which then rides the *same* fused
        path — one round trip per report, identical wire format.  Vector
        reports without a scalarizing pruner raise (storing only one
        objective silently would corrupt pruning decisions)."""
        step = int(step)
        study = self.study
        directions = study.directions
        direction = directions[0] if len(directions) == 1 else StudyDirection.MINIMIZE
        scalarize = getattr(study.pruner, "scalarize", None)
        spec_probe = getattr(study.pruner, "spec", None)
        probe = spec_probe() if callable(spec_probe) else None
        vector: "list[float] | None" = None
        if isinstance(value, (list, tuple)) or (
            hasattr(value, "__len__") and not isinstance(value, str)
        ):
            vector = [float(v) for v in value]
            if len(directions) > 1 and len(vector) != len(directions):
                raise ValueError(
                    f"vector report has {len(vector)} entries for "
                    f"{len(directions)} study directions"
                )
            if callable(scalarize):
                value = float(scalarize(vector, directions))
            elif probe is not None and probe.get("name") in ("nop", "none"):
                # no pruning decisions to corrupt: keep objective 0 as the
                # scalar stream entry (per-objective curves land via the
                # iv_vec attr below)
                value = float(vector[0])
            else:
                raise ValueError(
                    "vector report needs a Pareto-aware pruner that can "
                    "scalarize it (e.g. ParetoPruner); got "
                    f"{type(study.pruner).__name__}"
                )
        elif len(directions) > 1 and callable(scalarize):
            # a raw scalar would enter the scalarized-loss stream unoriented
            # and unscaled — judged as MINIMIZE next to augmented-Chebyshev
            # losses, silently corrupting every peer's prune decision
            raise ValueError(
                f"multi-objective study with {type(study.pruner).__name__}: "
                f"report all {len(directions)} objectives as a vector, not a scalar"
            )
        else:
            value = float(value)
        spec = probe
        scalarizing = callable(scalarize)
        storage = study._storage
        fused = spec is not None and (len(directions) == 1 or scalarizing)
        # per-objective vectors persist as the iv_vec:<step> system attr,
        # ordered BEFORE the scalar write so the hosted IV store's re-encode
        # (triggered by the scalar) already sees it.  Keeping the 1-frame
        # report contract: a raw remote/sharded client folds both ops into
        # one call_batch frame; CachedStorage has no call_batch but buffers
        # the attr op and flushes it on the SAME frame as the fused report.
        attr_op = None
        if vector is not None and len(vector) > 1:
            attr_op = (self._trial_id, iv_vec_key(step), vector)
        batch = getattr(storage, "call_batch", None) if attr_op else None
        if fused and attr_op and callable(batch):
            results = batch([
                ("set_trial_system_attr", attr_op),
                ("report_and_prune",
                 (study._study_id, self._trial_id, step, value, spec, direction)),
            ])
            self._prune_decision = (step, bool(results[1]))
        else:
            if attr_op is not None:
                storage.set_trial_system_attr(*attr_op)
            # no span of its own: storage.report_and_prune / the client RPC
            # span directly below covers the whole storage round trip already
            if fused:
                decision = storage.report_and_prune(
                    study._study_id, self._trial_id, step, value, spec, direction
                )
                self._prune_decision = (step, bool(decision))
            else:
                storage.set_trial_intermediate_value(self._trial_id, step, value)
                self._prune_decision = None
        if self._last_report is None or step >= self._last_report[0]:
            self._last_report = (step, value)
        self._cached = None

    @property
    def last_reported(self) -> "tuple[int, float] | None":
        """(step, value) of this process's highest-step ``report`` so far —
        the same value ``FrozenTrial.last_step`` would select, so e.g. the
        tune scheduler can record a pruned trial's final value without a
        refetch even when steps were reported out of order."""
        return self._last_report

    def should_prune(self) -> bool:
        """Ask the study's pruner whether this trial should stop
        ('should_prune API').  Answers from the fused decision cached by the
        preceding ``report`` when available (no storage round trip);
        otherwise evaluates the pruner client-side."""
        if self._prune_decision is not None:
            return self._prune_decision[1]
        trial = self.study._storage.get_trial(self._trial_id)
        return self.study.pruner.prune(self.study, trial)

    def prune(self) -> None:
        """Convenience: raise :class:`TrialPruned`."""
        raise TrialPruned(f"trial {self.number} pruned")

    # -- attrs --------------------------------------------------------------------

    def set_user_attr(self, key: str, value: Any) -> None:
        self.study._storage.set_trial_user_attr(self._trial_id, key, value)
        self._cached = None

    def set_system_attr(self, key: str, value: Any) -> None:
        self.study._storage.set_trial_system_attr(self._trial_id, key, value)
        self._cached = None


class FixedTrial(BaseTrial):
    """Replays a fixed parameter set through an objective (paper §2.2).

    The suggest API returns the user-supplied values; unknown parameters raise.
    Use it to *deploy* the best configuration through the very same
    define-by-run objective used for search::

        best = study.best_trial
        objective(FixedTrial(best.params))
    """

    def __init__(self, params: dict[str, Any], number: int = 0):
        self._params = dict(params)
        self._suggested: dict[str, BaseDistribution] = {}
        self._user_attrs: dict[str, Any] = {}
        self._system_attrs: dict[str, Any] = {}
        self._intermediate: dict[int, float] = {}
        self.number = number

    @property
    def params(self) -> dict[str, Any]:
        return dict(self._params)

    @property
    def user_attrs(self) -> dict[str, Any]:
        return dict(self._user_attrs)

    def _suggest(self, name: str, distribution: BaseDistribution) -> Any:
        if name not in self._params:
            raise ValueError(f"FixedTrial has no value for parameter {name!r}")
        value = self._params[name]
        internal = distribution.to_internal_repr(value)
        if not distribution._contains(internal):
            raise ValueError(
                f"FixedTrial value {value!r} for {name!r} is outside {distribution!r}"
            )
        self._suggested[name] = distribution
        return distribution.to_external_repr(internal)

    def report(self, value: float, step: int) -> None:
        self._intermediate[int(step)] = float(value)

    def should_prune(self) -> bool:
        return False

    def set_user_attr(self, key: str, value: Any) -> None:
        self._user_attrs[key] = value

    def set_system_attr(self, key: str, value: Any) -> None:
        self._system_attrs[key] = value
