"""Hyperparameter importance — feeds the dashboard (paper Fig. 8 style analysis).

A pandas/sklearn-free importance evaluator: fANOVA-style variance attribution
using a random-forest-of-stumps surrogate is overkill without sklearn, so we
use the standard pragmatic pair:

* per-parameter *variance explained* by a binned conditional-mean model
  (one-way fANOVA main effect on the empirical distribution), and
* Spearman |rank correlation| as a cross-check.

Both operate on completed trials only and normalize to sum 1.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from .distributions import CategoricalDistribution
from .frozen import StudyDirection, TrialState

if TYPE_CHECKING:
    from .study import Study

__all__ = ["param_importances", "spearman_importances"]


def _collect(study: "Study"):
    # Importance is defined for single-objective studies only: with multiple
    # objectives there is no scalar target to attribute variance to, so the
    # evaluators degrade to an empty result instead of silently ranking
    # against the first objective (or raising on trials with empty values).
    if len(study.directions) != 1:
        return [], []
    trials = [
        t
        for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,))
        if t.values is not None and len(t.values) >= 1 and np.isfinite(t.values[0])
    ]
    names = sorted({n for t in trials for n in t.params})
    return trials, names


def param_importances(study: "Study", n_bins: int = 8) -> dict[str, float]:
    """Main-effect variance ratio per parameter (one-way fANOVA on bins).

    Degrades gracefully: multi-objective studies and studies with fewer than
    two usable COMPLETE trials yield ``{}`` (nothing to attribute) rather
    than raising.
    """
    trials, names = _collect(study)
    if len(trials) < 2:
        return {}
    if len(trials) < 4:
        return {n: 0.0 for n in names}
    y = np.array([t.values[0] for t in trials], dtype=float)
    total_var = float(y.var())
    if total_var <= 0:
        return {n: 0.0 for n in names}

    scores: dict[str, float] = {}
    for name in names:
        xs, ys = [], []
        for t, v in zip(trials, y):
            if name in t.params:
                dist = t.distributions[name]
                xs.append(dist.to_internal_repr(t.params[name]))
                ys.append(v)
        if len(xs) < 4:
            scores[name] = 0.0
            continue
        xs = np.asarray(xs)
        ys = np.asarray(ys)
        dist = next(t.distributions[name] for t in trials if name in t.distributions)
        if isinstance(dist, CategoricalDistribution):
            bins = xs.astype(int)
        else:
            lo, hi = xs.min(), xs.max()
            if hi <= lo:
                scores[name] = 0.0
                continue
            if getattr(dist, "log", False):
                xs_t = np.log(np.maximum(xs, 1e-300))
                lo, hi = xs_t.min(), xs_t.max()
                bins = np.minimum(((xs_t - lo) / (hi - lo) * n_bins).astype(int), n_bins - 1)
            else:
                bins = np.minimum(((xs - lo) / (hi - lo) * n_bins).astype(int), n_bins - 1)
        # variance explained by bin-conditional means
        explained = 0.0
        for b in np.unique(bins):
            m = bins == b
            explained += m.sum() * (ys[m].mean() - ys.mean()) ** 2
        scores[name] = float(explained / len(ys) / ys.var()) if ys.var() > 0 else 0.0

    total = sum(scores.values())
    if total > 0:
        scores = {k: v / total for k, v in scores.items()}
    return dict(sorted(scores.items(), key=lambda kv: -kv[1]))


def spearman_importances(study: "Study") -> dict[str, float]:
    """|Spearman rank correlation| per parameter; same degradation rules as
    :func:`param_importances` (``{}`` on multi-objective / <2 trials)."""
    trials, names = _collect(study)
    if len(trials) < 2:
        return {}
    if len(trials) < 4:
        return {n: 0.0 for n in names}
    y = np.array([t.values[0] for t in trials], dtype=float)
    out = {}
    for name in names:
        xs, ys = [], []
        for t, v in zip(trials, y):
            if name in t.params:
                xs.append(t.distributions[name].to_internal_repr(t.params[name]))
                ys.append(v)
        if len(xs) < 4 or np.std(xs) == 0:
            out[name] = 0.0
            continue
        rx = np.argsort(np.argsort(xs)).astype(float)
        ry = np.argsort(np.argsort(ys)).astype(float)
        denom = rx.std() * ry.std()
        out[name] = float(abs(np.mean((rx - rx.mean()) * (ry - ry.mean())) / denom)) if denom > 0 else 0.0
    total = sum(out.values())
    if total > 0:
        out = {k: v / total for k, v in out.items()}
    return dict(sorted(out.items(), key=lambda kv: -kv[1]))
