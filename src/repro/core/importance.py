"""Hyperparameter importance — feeds the dashboard (paper Fig. 8 style analysis).

Three evaluators, all pandas/sklearn-free:

* :func:`fanova_importances` — **fANOVA** (Hutter et al., ICML'14) on a
  bootstrap ensemble of regression trees fit to the observation store's
  model-space design matrix.  Each tree partitions the unit hypercube into
  leaf boxes; the functional-ANOVA main effect of parameter *j* is the
  variance of the tree's marginal prediction over axis *j* (piecewise
  constant over the tree's axis-*j* split segments), as a fraction of the
  tree's total prediction variance.  Falls back to the Spearman evaluator
  when there is too little data to grow trees.
* :func:`param_importances` — per-parameter *variance explained* by a binned
  conditional-mean model (one-way fANOVA main effect on the empirical
  distribution).
* :func:`spearman_importances` — |Spearman rank correlation| as a
  cross-check.

All operate on completed trials only and normalize to sum 1.  On
multi-objective studies each returns per-objective importances keyed by
objective index (``{0: {...}, 1: {...}}``); pass ``objective=k`` for one
flat dict.  Single-objective results are bit-identical to the historical
single-objective-only evaluators (pinned by ``tests/test_dashboard.py``).
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from .distributions import CategoricalDistribution
from .frozen import TrialState

if TYPE_CHECKING:
    from .study import Study

__all__ = ["param_importances", "spearman_importances", "fanova_importances"]


def _collect(study: "Study", objective: int = 0):
    trials = [
        t
        for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,))
        if t.values is not None
        and len(t.values) > objective
        and np.isfinite(t.values[objective])
    ]
    names = sorted({n for t in trials for n in t.params})
    return trials, names


def _per_objective(study: "Study", objective, fn):
    """Shared multi-objective dispatch: ``objective=None`` on an MO study
    fans ``fn`` out per objective index; otherwise one flat dict."""
    n_obj = len(study.directions)
    if objective is None and n_obj > 1:
        return {k: fn(k) for k in range(n_obj)}
    return fn(int(objective) if objective is not None else 0)


def param_importances(
    study: "Study", n_bins: int = 8, objective: "int | None" = None
) -> dict:
    """Main-effect variance ratio per parameter (one-way fANOVA on bins).

    Degrades gracefully: studies with fewer than two usable COMPLETE trials
    yield ``{}`` (nothing to attribute) rather than raising.  Multi-objective
    studies return ``{objective_index: {param: importance}}`` unless a single
    ``objective`` is requested.
    """
    return _per_objective(study, objective, lambda k: _binned(study, n_bins, k))


def _binned(study: "Study", n_bins: int, objective: int) -> dict[str, float]:
    trials, names = _collect(study, objective)
    if len(trials) < 2:
        return {}
    if len(trials) < 4:
        return {n: 0.0 for n in names}
    y = np.array([t.values[objective] for t in trials], dtype=float)
    total_var = float(y.var())
    if total_var <= 0:
        return {n: 0.0 for n in names}

    scores: dict[str, float] = {}
    for name in names:
        xs, ys = [], []
        for t, v in zip(trials, y):
            if name in t.params:
                dist = t.distributions[name]
                xs.append(dist.to_internal_repr(t.params[name]))
                ys.append(v)
        if len(xs) < 4:
            scores[name] = 0.0
            continue
        xs = np.asarray(xs)
        ys = np.asarray(ys)
        dist = next(t.distributions[name] for t in trials if name in t.distributions)
        if isinstance(dist, CategoricalDistribution):
            bins = xs.astype(int)
        else:
            lo, hi = xs.min(), xs.max()
            if hi <= lo:
                scores[name] = 0.0
                continue
            if getattr(dist, "log", False):
                xs_t = np.log(np.maximum(xs, 1e-300))
                lo, hi = xs_t.min(), xs_t.max()
                bins = np.minimum(((xs_t - lo) / (hi - lo) * n_bins).astype(int), n_bins - 1)
            else:
                bins = np.minimum(((xs - lo) / (hi - lo) * n_bins).astype(int), n_bins - 1)
        # variance explained by bin-conditional means
        explained = 0.0
        for b in np.unique(bins):
            m = bins == b
            explained += m.sum() * (ys[m].mean() - ys.mean()) ** 2
        scores[name] = float(explained / len(ys) / ys.var()) if ys.var() > 0 else 0.0

    total = sum(scores.values())
    if total > 0:
        scores = {k: v / total for k, v in scores.items()}
    return dict(sorted(scores.items(), key=lambda kv: -kv[1]))


def spearman_importances(study: "Study", objective: "int | None" = None) -> dict:
    """|Spearman rank correlation| per parameter; same degradation rules as
    :func:`param_importances` (``{}`` on <2 trials, per-objective dict on
    multi-objective studies)."""
    return _per_objective(study, objective, lambda k: _spearman(study, k))


def _spearman(study: "Study", objective: int) -> dict[str, float]:
    trials, names = _collect(study, objective)
    if len(trials) < 2:
        return {}
    if len(trials) < 4:
        return {n: 0.0 for n in names}
    y = np.array([t.values[objective] for t in trials], dtype=float)
    out = {}
    for name in names:
        xs, ys = [], []
        for t, v in zip(trials, y):
            if name in t.params:
                xs.append(t.distributions[name].to_internal_repr(t.params[name]))
                ys.append(v)
        if len(xs) < 4 or np.std(xs) == 0:
            out[name] = 0.0
            continue
        rx = np.argsort(np.argsort(xs)).astype(float)
        ry = np.argsort(np.argsort(ys)).astype(float)
        denom = rx.std() * ry.std()
        out[name] = float(abs(np.mean((rx - rx.mean()) * (ry - ry.mean())) / denom)) if denom > 0 else 0.0
    total = sum(out.values())
    if total > 0:
        out = {k: v / total for k, v in out.items()}
    return dict(sorted(out.items(), key=lambda kv: -kv[1]))


# ---------------------------------------------------------------------------
# fANOVA on the columnar design matrix
# ---------------------------------------------------------------------------


def _fit_tree(X, y, idx, max_depth: int, min_leaf: int):
    """Grow one variance-reduction regression tree over bootstrap rows
    ``idx`` and return its leaf partition of the unit hypercube as
    ``(lo, hi, value)`` arrays — the only thing fANOVA needs.

    Splits are exact best-SSE scans, vectorized per (node, feature) with
    prefix sums over the sorted column."""
    d = X.shape[1]
    leaves_lo: list[np.ndarray] = []
    leaves_hi: list[np.ndarray] = []
    leaves_v: list[float] = []
    stack = [(idx, np.zeros(d), np.ones(d), 0)]
    while stack:
        rows, lo, hi, depth = stack.pop()
        ys = y[rows]
        split = None
        if depth < max_depth and rows.size >= 2 * min_leaf and ys.max() > ys.min():
            best_sse = np.inf
            m = rows.size
            cuts = np.arange(1, m)
            for j in range(d):
                xs = X[rows, j]
                order = np.argsort(xs, kind="stable")
                xs_s, ys_s = xs[order], ys[order]
                valid = (xs_s[1:] > xs_s[:-1]) & (cuts >= min_leaf) & (m - cuts >= min_leaf)
                if not valid.any():
                    continue
                csum = np.cumsum(ys_s)
                csq = np.cumsum(ys_s * ys_s)
                ls, lq = csum[:-1], csq[:-1]
                rs, rq = csum[-1] - ls, csq[-1] - lq
                with np.errstate(invalid="ignore"):
                    sse = (lq - ls * ls / cuts) + (rq - rs * rs / (m - cuts))
                sse[~valid] = np.inf
                k = int(np.argmin(sse))
                if sse[k] < best_sse:
                    best_sse = float(sse[k])
                    # k indexes cut "left count = k+1": boundary midpoint
                    split = (j, 0.5 * float(xs_s[k] + xs_s[k + 1]))
        if split is None:
            leaves_lo.append(lo)
            leaves_hi.append(hi)
            leaves_v.append(float(ys.mean()))
            continue
        j, thr = split
        go_left = X[rows, j] <= thr
        hi_l = hi.copy()
        hi_l[j] = thr
        lo_r = lo.copy()
        lo_r[j] = thr
        stack.append((rows[go_left], lo, hi_l, depth + 1))
        stack.append((rows[~go_left], lo_r, hi, depth + 1))
    return np.asarray(leaves_lo), np.asarray(leaves_hi), np.asarray(leaves_v)


def _fanova_tree_main_effects(lo, hi, v) -> "tuple[np.ndarray, float]":
    """Per-parameter main-effect variances of one tree's piecewise-constant
    predictor over the unit hypercube.

    With leaf boxes :math:`B_l` (volume = weight :math:`w_l`, value
    :math:`v_l`): total variance :math:`V = \\sum_l w_l v_l^2 - \\mu^2`
    (:math:`\\mu = \\sum_l w_l v_l`), and the axis-*j* marginal
    :math:`f_j(x) = \\sum_{l: x \\in B_l|_j} v_l \\, w_l / |B_l|_j` is
    piecewise constant over the tree's axis-*j* split segments, so
    :math:`V_j = \\int (f_j - \\mu)^2` is an exact sum over segments."""
    d = lo.shape[1]
    w = np.prod(hi - lo, axis=1)
    mu = float((w * v).sum())
    V = float((w * v * v).sum() - mu * mu)
    out = np.zeros(d)
    if V <= 1e-18:
        return out, 0.0
    for j in range(d):
        bounds = np.unique(np.concatenate((lo[:, j], hi[:, j])))
        if bounds.size <= 2:  # never split on j -> flat marginal
            continue
        seg_lo, seg_hi = bounds[:-1], bounds[1:]
        lenj = hi[:, j] - lo[:, j]
        contain = (seg_lo[:, None] >= lo[None, :, j] - 1e-12) & (
            seg_hi[:, None] <= hi[None, :, j] + 1e-12
        )
        f = contain @ (v * w / lenj)
        out[j] = float(((seg_hi - seg_lo) * (f - mu) ** 2).sum())
    return out, V


def fanova_importances(
    study: "Study",
    objective: "int | None" = None,
    n_trees: int = 16,
    max_depth: int = 6,
    min_samples_leaf: int = 3,
    seed: int = 0,
) -> dict:
    """fANOVA importances on the observation store's design matrix.

    Reads the store's model-space columns directly (log-transformed numerics
    / categorical indices — no re-encoding), normalizes each to [0, 1],
    imputes unsuggested cells with the column mean, fits ``n_trees``
    bootstrap regression trees and averages each parameter's main-effect
    variance fraction across the ensemble.  The store is revision-gated, so
    calling this per dashboard poll re-fits only when new trials landed
    (callers cache on ``store.version`` — see ``core/analytics.py``).

    Falls back to :func:`spearman_importances` when fewer than
    ``max(8, 4 * min_samples_leaf)`` usable rows exist or the objective has
    zero variance.  Multi-objective studies return per-objective dicts keyed
    by objective index unless ``objective`` is given.
    """

    def one(k: int) -> dict[str, float]:
        store = study.observations()
        names = store.param_names()
        if not names:
            return {}
        _, states, Vm, arity, _, cols = store.snapshot_mo()
        if Vm.shape[1] <= k:
            return _spearman(study, k)
        y_all = Vm[:, k]
        mask = (states == int(TrialState.COMPLETE)) & np.isfinite(y_all)
        n = int(mask.sum())
        if n < max(8, 4 * min_samples_leaf) or float(y_all[mask].var()) <= 0:
            return _spearman(study, k)
        y = y_all[mask].astype(float)
        X = np.empty((n, len(names)))
        for jcol, name in enumerate(names):
            col = cols.get(name)
            c = (
                col[mask].astype(float).copy()
                if col is not None
                else np.full(n, np.nan)
            )
            obs = np.isfinite(c)
            if obs.any():
                c[~obs] = float(c[obs].mean())
                clo, chi = float(c.min()), float(c.max())
                c = (c - clo) / (chi - clo) if chi > clo else np.full(n, 0.5)
            else:
                c = np.full(n, 0.5)
            X[:, jcol] = c
        rng = np.random.default_rng(seed)
        imp = np.zeros(len(names))
        used = 0
        for _ in range(int(n_trees)):
            idx = rng.integers(0, n, n)
            lo, hi, v = _fit_tree(X, y, idx, int(max_depth), int(min_samples_leaf))
            vj, V = _fanova_tree_main_effects(lo, hi, v)
            if V > 0:
                imp += vj / V
                used += 1
        if used == 0:
            return _spearman(study, k)
        imp /= used
        total = float(imp.sum())
        if total > 0:
            imp = imp / total
        out = {name: float(w) for name, w in zip(names, imp)}
        return dict(sorted(out.items(), key=lambda kv: -kv[1]))

    return _per_objective(study, objective, one)
