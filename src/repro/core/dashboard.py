"""Static-HTML dashboard (paper §4, Fig. 8) — zero-dependency.

Generates a self-contained HTML file with hand-rolled SVG:

* optimization-history plot (objective value vs trial number + best-so-far),
* intermediate-value learning curves (pruned trials drawn dimmed),
* parallel-coordinates plot of sampled parameters,
* parameter importances,
* the trials table.

Real-time use: re-render on a timer (``watch -n10``) or from a study callback;
the render reads only storage, so it works against a live distributed study.
"""

from __future__ import annotations

import html
import math
from typing import TYPE_CHECKING

from .frozen import StudyDirection, TrialState
from .importance import param_importances

if TYPE_CHECKING:
    from .study import Study

__all__ = ["render_dashboard", "save_dashboard"]

W, H, PAD = 640, 300, 40


def _scale(vs, lo, hi, out_lo, out_hi):
    if hi <= lo:
        return [0.5 * (out_lo + out_hi) for _ in vs]
    return [out_lo + (v - lo) / (hi - lo) * (out_hi - out_lo) for v in vs]


def _poly(points: list[tuple[float, float]], color: str, width: float = 1.5, opacity: float = 1.0) -> str:
    pts = " ".join(f"{x:.1f},{y:.1f}" for x, y in points)
    return (
        f'<polyline fill="none" stroke="{color}" stroke-width="{width}" '
        f'opacity="{opacity}" points="{pts}"/>'
    )


def _svg(body: str, w: int = W, h: int = H) -> str:
    return (
        f'<svg viewBox="0 0 {w} {h}" width="{w}" height="{h}" '
        f'style="background:#fff;border:1px solid #ddd">{body}</svg>'
    )


def _axis_frame(w: int = W, h: int = H) -> str:
    return (
        f'<line x1="{PAD}" y1="{h-PAD}" x2="{w-10}" y2="{h-PAD}" stroke="#888"/>'
        f'<line x1="{PAD}" y1="10" x2="{PAD}" y2="{h-PAD}" stroke="#888"/>'
    )


def _history_svg(study: "Study") -> str:
    trials = [
        t for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,))
        if t.values and math.isfinite(t.values[0])
    ]
    if not trials:
        return _svg('<text x="20" y="40">no completed trials</text>')
    xs = [t.number for t in trials]
    ys = [t.values[0] for t in trials]
    lo, hi = min(ys), max(ys)
    sx = _scale(xs, min(xs), max(xs), PAD, W - 10)
    sy = _scale(ys, lo, hi, H - PAD, 10)
    pts = "".join(
        f'<circle cx="{x:.1f}" cy="{y:.1f}" r="2.5" fill="#3b6fb6"/>' for x, y in zip(sx, sy)
    )
    # best-so-far line (first objective on multi-objective studies)
    best, bests = None, []
    minimize = study.directions[0] == StudyDirection.MINIMIZE
    for y in ys:
        best = y if best is None else (min(best, y) if minimize else max(best, y))
        bests.append(best)
    sb = _scale(bests, lo, hi, H - PAD, 10)
    line = _poly(list(zip(sx, sb)), "#c0392b", 2.0)
    labels = (
        f'<text x="{PAD}" y="{H-10}" font-size="11">trial #</text>'
        f'<text x="5" y="20" font-size="11">value [{lo:.4g}, {hi:.4g}]</text>'
    )
    return _svg(_axis_frame() + pts + line + labels)


def _curves_svg(study: "Study", max_curves: int = 200) -> str:
    trials = [t for t in study.get_trials(deepcopy=False) if t.intermediate_values]
    if not trials:
        return _svg('<text x="20" y="40">no intermediate values reported</text>')
    trials = trials[-max_curves:]
    all_v = [v for t in trials for v in t.intermediate_values.values() if math.isfinite(v)]
    all_s = [s for t in trials for s in t.intermediate_values]
    if not all_v:
        return _svg('<text x="20" y="40">no finite intermediate values</text>')
    lo, hi = min(all_v), max(all_v)
    slo, shi = min(all_s), max(all_s)
    body = [_axis_frame()]
    for t in trials:
        steps = sorted(t.intermediate_values)
        vs = [t.intermediate_values[s] for s in steps]
        sx = _scale(steps, slo, shi, PAD, W - 10)
        sy = _scale(vs, lo, hi, H - PAD, 10)
        if t.state == TrialState.PRUNED:
            body.append(_poly(list(zip(sx, sy)), "#bbb", 1.0, 0.6))
        elif t.state == TrialState.COMPLETE:
            body.append(_poly(list(zip(sx, sy)), "#2b8a3e", 1.3, 0.9))
        else:
            body.append(_poly(list(zip(sx, sy)), "#e67e22", 1.3, 0.9))
    body.append(f'<text x="{PAD}" y="{H-10}" font-size="11">step</text>')
    return _svg("".join(body))


def _parallel_svg(study: "Study") -> str:
    trials = [
        t for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,))
        if t.values and math.isfinite(t.values[0])
    ]
    if len(trials) < 2:
        return _svg('<text x="20" y="40">need >= 2 completed trials</text>')
    names = sorted({n for t in trials for n in t.params})
    axes = names + ["value"]
    n_ax = len(axes)
    xs = _scale(list(range(n_ax)), 0, n_ax - 1, PAD, W - 20)

    cols: dict[str, list[float]] = {}
    for name in names:
        vals = []
        for t in trials:
            if name in t.params:
                vals.append(t.distributions[name].to_internal_repr(t.params[name]))
        cols[name] = vals
    values = [t.values[0] for t in trials]
    vlo, vhi = min(values), max(values)

    body = []
    for i, ax in enumerate(axes):
        body.append(f'<line x1="{xs[i]:.0f}" y1="15" x2="{xs[i]:.0f}" y2="{H-25}" stroke="#999"/>')
        body.append(
            f'<text x="{xs[i]:.0f}" y="{H-8}" font-size="9" text-anchor="middle">{html.escape(ax[:14])}</text>'
        )
    for t, v in zip(trials, values):
        pts = []
        for i, name in enumerate(names):
            if name not in t.params:
                continue
            col = cols[name]
            lo, hi = min(col), max(col)
            y = _scale([t.distributions[name].to_internal_repr(t.params[name])], lo, hi, H - 25, 15)[0]
            pts.append((xs[i], y))
        y = _scale([v], vlo, vhi, H - 25, 15)[0]
        pts.append((xs[-1], y))
        # color by objective (first one on MO studies): blue (good) to red (bad)
        q = 0.0 if vhi <= vlo else (v - vlo) / (vhi - vlo)
        if study.directions[0] == StudyDirection.MAXIMIZE:
            q = 1 - q
        color = f"rgb({int(60+180*q)},{int(110-60*q)},{int(200-160*q)})"
        body.append(_poly(pts, color, 1.0, 0.55))
    return _svg("".join(body))


def _importance_svg(study: "Study") -> str:
    try:
        imps = param_importances(study)
    except Exception:
        imps = {}
    # MO studies return per-objective dicts keyed by objective index
    groups = imps if imps and isinstance(next(iter(imps.values()), None), dict) else {None: imps}
    body = []
    y = 20
    for obj, grp in groups.items():
        if not grp:
            continue
        if obj is not None:
            body.append(f'<text x="20" y="{y}" font-size="10" font-weight="bold">objective {obj}</text>')
            y += 16
        for name, v in list(grp.items())[:12]:
            w = v * (W - 180)
            body.append(f'<rect x="150" y="{y-10}" width="{max(w,1):.0f}" height="12" fill="#3b6fb6"/>')
            body.append(f'<text x="145" y="{y}" font-size="10" text-anchor="end">{html.escape(name[:20])}</text>')
            body.append(f'<text x="{155+w:.0f}" y="{y}" font-size="10">{v:.2f}</text>')
            y += 20
    if not body:
        return _svg('<text x="20" y="40">importances unavailable</text>')
    return _svg("".join(body), W, max(y + 10, 80))


def _table(study: "Study", limit: int = 100) -> str:
    rows = study.trials_dataframe()[-limit:]
    if not rows:
        return "<p>no trials</p>"
    cols = sorted({k for r in rows for k in r})
    head = "".join(f"<th>{html.escape(c)}</th>" for c in cols)
    body = []
    for r in rows:
        tds = "".join(f"<td>{html.escape(str(r.get(c, '')))[:24]}</td>" for c in cols)
        body.append(f"<tr>{tds}</tr>")
    return (
        '<table border="1" cellspacing="0" cellpadding="3" style="font-size:11px">'
        f"<tr>{head}</tr>{''.join(body)}</table>"
    )


def _pareto_svg(study: "Study") -> str:
    """Objective-space scatter for 2-objective studies: completed trials in
    grey, the engine's Pareto front (``Study.pareto_front``) highlighted."""
    values, numbers = study.pareto_front()
    trials = [
        t for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,))
        if t.values and len(t.values) == 2 and all(math.isfinite(v) for v in t.values)
    ]
    if not trials:
        return _svg('<text x="20" y="40">no completed trials</text>')
    xs = [t.values[0] for t in trials]
    ys = [t.values[1] for t in trials]
    sx = _scale(xs, min(xs), max(xs), PAD, W - 10)
    sy = _scale(ys, min(ys), max(ys), H - PAD, 10)
    front = set(numbers.tolist())
    pts = "".join(
        f'<circle cx="{x:.1f}" cy="{y:.1f}" r="{3.5 if t.number in front else 2.0}" '
        f'fill="{"#c0392b" if t.number in front else "#b8c4d0"}"/>'
        for t, x, y in zip(trials, sx, sy)
    )
    labels = (
        f'<text x="{PAD}" y="{H-10}" font-size="11">objective 0</text>'
        f'<text x="5" y="20" font-size="11">objective 1</text>'
        f'<text x="{W-180}" y="20" font-size="11" fill="#c0392b">'
        f"Pareto front ({len(front)} trials)</text>"
    )
    return _svg(_axis_frame() + pts + labels)


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024.0 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024.0
    return f"{n:.1f} GiB"


def _throughput_svg(samples: "list[float]", w: int = 320, h: int = 80) -> str:
    """Sparkline of trial throughput (finished trials/s per poll tick)."""
    if not samples:
        return _svg('<text x="10" y="20" font-size="10">no samples yet</text>', w, h)
    hi = max(max(samples), 1e-9)
    sx = _scale(list(range(len(samples))), 0, max(len(samples) - 1, 1), 5, w - 5)
    sy = _scale(samples, 0.0, hi, h - 15, 5)
    line = _poly(list(zip(sx, sy)), "#2b8a3e", 1.5)
    area = ""
    if len(samples) >= 2:
        pts = " ".join(f"{x:.1f},{y:.1f}" for x, y in zip(sx, sy))
        area = (
            f'<polygon fill="#2b8a3e" opacity="0.15" points="'
            f'{sx[0]:.1f},{h-15} {pts} {sx[-1]:.1f},{h-15}"/>'
        )
    label = (
        f'<text x="5" y="{h-4}" font-size="9">trials/s &middot; '
        f"now {samples[-1]:.2f} &middot; peak {hi:.2f}</text>"
    )
    return _svg(area + line + label, w, h)


def _metrics_panel_html(metrics: "dict | None") -> str:
    """Server-side telemetry panel from a ``get_server_metrics`` payload."""
    if not metrics:
        return "<p>server metrics unavailable (storage has no metrics RPC)</p>"
    up = metrics.get("uptime_s", 0.0)
    summary = (
        f"uptime {up:.0f}s &middot; "
        f"connections {metrics.get('active_connections', 0)} active &middot; "
        f"frames {metrics.get('frames_in', 0)} in / {metrics.get('frames_out', 0)} out &middot; "
        f"{_fmt_bytes(metrics.get('bytes_in', 0))} in / {_fmt_bytes(metrics.get('bytes_out', 0))} out &middot; "
        f"spec cache {metrics.get('spec_cache_hits', 0)} hits"
    )
    methods = metrics.get("methods", {})
    if not methods:
        return f"<p>{summary}</p><p>no RPCs served yet</p>"
    head = (
        "<tr><th>method</th><th>calls</th><th>errors</th><th>bytes out</th>"
        "<th>p50 ms</th><th>p95 ms</th><th>p99 ms</th><th>max ms</th></tr>"
    )
    rows = []
    for name in sorted(methods, key=lambda m: -methods[m].get("calls", 0)):
        m = methods[name]
        rows.append(
            f"<tr><td>{html.escape(str(name))}</td><td>{m.get('calls', 0)}</td>"
            f"<td>{m.get('errors', 0)}</td><td>{_fmt_bytes(m.get('bytes_out', 0))}</td>"
            f"<td>{m.get('p50', 0.0) * 1e3:.2f}</td><td>{m.get('p95', 0.0) * 1e3:.2f}</td>"
            f"<td>{m.get('p99', 0.0) * 1e3:.2f}</td><td>{m.get('max', 0.0) * 1e3:.2f}</td></tr>"
        )
    return (
        f"<p>{summary}</p>"
        '<table border="1" cellspacing="0" cellpadding="3" style="font-size:11px">'
        f"{head}{''.join(rows)}</table>"
    )


def render_dashboard(
    study: "Study",
    server_metrics: "dict | None" = None,
    throughput: "list[float] | None" = None,
) -> str:
    n_by_state = {}
    for t in study.get_trials(deepcopy=False):
        n_by_state[t.state.name] = n_by_state.get(t.state.name, 0) + 1
    directions = study.directions
    if len(directions) == 1:
        try:
            best = f"{study.best_value:.6g} (trial {study.best_trial.number})"
        except ValueError:
            best = "n/a"
    else:
        best = f"{len(study.pareto_front()[1])} Pareto-optimal trials"
    summary = ", ".join(f"{k}: {v}" for k, v in sorted(n_by_state.items()))
    dir_str = ", ".join(d.name.lower() for d in directions)
    pareto_section = (
        f"<h2>Pareto front (objective space)</h2>{_pareto_svg(study)}"
        if len(directions) == 2 else ""
    )
    live_section = ""
    if server_metrics is not None or throughput is not None:
        spark = _throughput_svg(throughput or [])
        live_section = (
            f"<h2>Live server metrics</h2>{spark}"
            f"{_metrics_panel_html(server_metrics)}"
        )
    return f"""<!doctype html>
<html><head><meta charset="utf-8"><title>{html.escape(study.study_name)}</title>
<style>body{{font-family:sans-serif;margin:20px}} h2{{margin-top:28px}}</style></head>
<body>
<h1>Study: {html.escape(study.study_name)}</h1>
<p>direction: {dir_str} &middot; trials: {summary} &middot; best: {best}</p>
{live_section}
{pareto_section}
<h2>Optimization history</h2>{_history_svg(study)}
<h2>Learning curves (intermediate values)</h2>{_curves_svg(study)}
<h2>Parallel coordinates</h2>{_parallel_svg(study)}
<h2>Parameter importances</h2>{_importance_svg(study)}
<h2>Trials</h2>{_table(study)}
</body></html>"""


def save_dashboard(study: "Study", path: str) -> str:
    htm = render_dashboard(study)
    with open(path, "w") as f:
        f.write(htm)
    return path


def main(argv: "list[str] | None" = None) -> None:
    """Render a dashboard for any storage URL — including a *live* remote
    study being optimized by a worker fleet:

        python -m repro.core.dashboard remote://host:9000 my-study out.html --watch 10
    """
    import argparse
    import time

    from .storage import get_storage
    from .study import load_study

    ap = argparse.ArgumentParser(description="render the study dashboard to HTML")
    ap.add_argument("storage", help="storage URL (sqlite:///, journal://, remote://)")
    ap.add_argument("study_name")
    ap.add_argument("out", help="output HTML path")
    ap.add_argument("--watch", type=float, default=0.0, metavar="SECONDS",
                    help="re-render every N seconds (0 = render once)")
    ap.add_argument("--live", action="store_true",
                    help="add the live panel: server metrics (when the storage"
                         " exposes get_server_metrics) + throughput sparkline;"
                         " polling is revision-gated, so idle ticks cost one"
                         " counter RPC and skip the re-render")
    ap.add_argument("--ticks", type=int, default=0, metavar="N",
                    help="with --watch: stop after N polls (0 = forever);"
                         " used by headless smoke tests")
    args = ap.parse_args(argv)

    # cache=True: render_dashboard reads the trial list several times per
    # tick, and --watch re-renders forever — fetch each finished trial once
    storage = get_storage(args.storage, cache=True)
    study = load_study(args.study_name, storage)
    sid = study._study_id

    def server_metrics():
        fn = getattr(storage, "get_server_metrics", None)
        if fn is None:
            return None
        try:
            return fn()
        except Exception:
            return None

    def n_finished():
        return sum(
            t.state.is_finished() for t in study.get_trials(deepcopy=False)
        )

    # one revision-gated poll loop, shared with the HTTP analytics service
    from .analytics import RevisionPoller

    poller = RevisionPoller(storage, sid)
    throughput: list[float] = []
    last_n, last_t = n_finished(), time.monotonic()
    tick = 0
    while True:
        tick += 1
        changed = poller.poll()
        if args.live:
            now = time.monotonic()
            n = n_finished() if changed else last_n
            dt = max(now - last_t, 1e-9)
            throughput.append((n - last_n) / dt if tick > 1 else 0.0)
            throughput = throughput[-120:]
            last_n, last_t = n, now
        if changed or tick == 1:
            htm = render_dashboard(
                study,
                server_metrics=server_metrics() if args.live else None,
                throughput=throughput if args.live else None,
            )
            with open(args.out, "w") as f:
                f.write(htm)
            n = len(study.get_trials(deepcopy=False))  # cache-local, no extra RPC
            print(f"rendered {n} trials -> {args.out}", flush=True)
        if args.watch <= 0 or (args.ticks and tick >= args.ticks):
            break
        time.sleep(args.watch)


if __name__ == "__main__":
    main()
