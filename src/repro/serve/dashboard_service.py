"""Live analytics HTTP service — the paper's §4 web-dashboard criterion as a
zero-dependency threaded server over any storage URL.

``python -m repro.serve.dashboard_service --storage remote://h1:4000,h2:4000``
serves a browser dashboard for every study behind the URL (inmemory object,
``remote://`` server, or comma-sharded server pool), with five live views
(optimization history, contour, slice, Pareto front, learning curves), fANOVA
parameter importances, a cluster metrics page, and a Prometheus-style
``/metrics`` endpoint.

The refresh path is revision-gated end to end: the browser polls
``/api/study/<name>/delta?since_rev=R&since_num=N``; the service answers with
one ``get_trials_revision`` RPC (through the same :class:`RevisionPoller` the
``--live`` terminal dashboard uses) and, when the revision is unchanged,
returns ``{"idle": true}`` without touching the trial data at all — an idle
study costs zero storage refetches (pinned by the
``records.*.refresh.noop/fetch`` telemetry counters in
``tests/test_dashboard_service.py``).  An active study ships only the rows
with ``number > N``: the columnar stores refresh watermark-incrementally and
the row walk starts at a ``searchsorted`` offset, so the poll is O(new
trials), not O(study).

Endpoints
---------

====================================  =======================================
``GET /``                             study index page (HTML)
``GET /study/<name>``                 live study dashboard (HTML + inline JS)
``GET /cluster``                      per-shard server metrics page (HTML)
``GET /metrics``                      Prometheus text format (telemetry)
``GET /api/studies``                  JSON study list
``GET /api/study/<name>/delta``       revision-gated incremental rows
``GET /api/study/<name>/views``       all five views (version-cached)
``GET /api/study/<name>/importance``  fANOVA + Spearman, per objective
``GET /api/cluster/metrics``          ``get_server_metrics`` fan-out
====================================  =======================================

Auth mirrors the storage server's scoped-token model: ``tokens`` entries are
either plain strings (full access) or ``{"token", "readonly", "studies"}``
dicts.  Every endpoint here is a read, so *read-only* tokens are accepted
everywhere; *study-scoped* tokens are confined to their studies' pages and
APIs and are denied on the global endpoints (``/metrics``, ``/cluster``,
``/api/studies``, ``/api/cluster/metrics``).
"""

from __future__ import annotations

import argparse
import html
import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any
from urllib.parse import parse_qs, unquote, urlparse

from ..core import telemetry
from ..core.analytics import RevisionPoller, StudyAnalytics, jsonable
from ..core.storage import get_storage
from ..core.study import load_study

__all__ = ["DashboardService", "main"]


# ---------------------------------------------------------------------------
# auth scopes (mirrors storage/server.py's token model, reads only)
# ---------------------------------------------------------------------------


class _Scope:
    __slots__ = ("studies",)

    def __init__(self, studies: "frozenset[str] | None" = None):
        # None = all studies; a frozenset of study *names* bounds the token.
        # `readonly` needs no field: the service has no write endpoint, so a
        # read-only token is as powerful here as a full one.
        self.studies = studies

    def allows_study(self, name: str) -> bool:
        return self.studies is None or name in self.studies

    @property
    def global_ok(self) -> bool:
        return self.studies is None


def _normalize_tokens(tokens) -> "dict[str, _Scope]":
    out: dict[str, _Scope] = {}
    for ent in tokens or []:
        if isinstance(ent, str):
            out[ent] = _Scope()
            continue
        studies = ent.get("studies")
        out[ent["token"]] = _Scope(
            frozenset(str(s) for s in studies) if studies is not None else None
        )
    return out


# ---------------------------------------------------------------------------
# service
# ---------------------------------------------------------------------------


class _StudyHandle:
    """One study's live state: the Study object, its analytics engine, and
    the shared revision poller."""

    __slots__ = ("study", "analytics", "poller", "lock")

    def __init__(self, study):
        self.study = study
        self.analytics = StudyAnalytics(study)
        self.poller = RevisionPoller(study._storage, study._study_id)
        self.lock = threading.Lock()


class DashboardService:
    """Threaded HTTP dashboard over one storage URL.  ``port=0`` binds an
    ephemeral port (read it back from :attr:`port` after :meth:`start`)."""

    def __init__(
        self,
        storage: "str | Any" = None,
        host: str = "127.0.0.1",
        port: int = 0,
        tokens: "list | None" = None,
    ):
        # cache=True: every study handle shares the incremental CachedStorage
        # proxy, so trial data is fetched once per revision across all views
        self._storage = get_storage(storage, cache=True)
        self._host = host
        self._port = int(port)
        self._scopes = _normalize_tokens(tokens)
        self._handles: dict[str, _StudyHandle] = {}
        self._lock = threading.Lock()
        self._httpd: "ThreadingHTTPServer | None" = None
        self._thread: "threading.Thread | None" = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "DashboardService":
        service = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                service._dispatch(self)

            def log_message(self, fmt, *args):  # quiet by default
                pass

        self._httpd = ThreadingHTTPServer((self._host, self._port), Handler)
        self._httpd.daemon_threads = True
        self._port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="dashboard-service", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    @property
    def port(self) -> int:
        return self._port

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self._port}"

    # -- study handles -------------------------------------------------------

    def _handle(self, name: str) -> _StudyHandle:
        with self._lock:
            h = self._handles.get(name)
            if h is None:
                h = _StudyHandle(load_study(name, self._storage))
                self._handles[name] = h
            return h

    # -- request dispatch ----------------------------------------------------

    def _scope_for(self, req) -> "_Scope | None":
        """Resolve the request's token to a scope (None = denied).  With no
        tokens configured, everything is open (full scope)."""
        if not self._scopes:
            return _Scope()
        auth = req.headers.get("Authorization", "")
        tok = auth[7:] if auth.startswith("Bearer ") else None
        if tok is None:
            q = parse_qs(urlparse(req.path).query)
            tok = (q.get("token") or [None])[0]
        return self._scopes.get(tok) if tok else None

    def _dispatch(self, req) -> None:
        telemetry.inc("dashboard.http.requests")
        try:
            parsed = urlparse(req.path)
            path = unquote(parsed.path)
            query = parse_qs(parsed.query)
            scope = self._scope_for(req)
            if scope is None:
                self._send(req, 401, "text/plain", b"unauthorized")
                return
            self._route(req, path, query, scope)
        except BrokenPipeError:
            pass
        except Exception as exc:  # surface, don't kill the handler thread
            try:
                self._send_json(req, 500, {"error": str(exc)})
            except Exception:
                pass

    def _route(self, req, path: str, query: dict, scope: _Scope) -> None:
        m = re.match(r"^/api/study/([^/]+)/(delta|views|importance)$", path)
        if m:
            name = m.group(1)
            if not scope.allows_study(name):
                self._send_json(req, 403, {"error": "token not scoped to study"})
                return
            kind = m.group(2)
            h = self._handle(name)
            if kind == "delta":
                self._send_json(req, 200, self._delta(h, query))
            elif kind == "views":
                with h.lock:
                    self._send_json(req, 200, h.analytics.views())
            else:
                with h.lock:
                    self._send_json(req, 200, h.analytics.importances())
            return

        m = re.match(r"^/study/([^/]+)$", path)
        if m:
            name = m.group(1)
            if not scope.allows_study(name):
                self._send(req, 403, "text/plain", b"token not scoped to study")
                return
            self._send(req, 200, "text/html", _study_page(name).encode())
            return

        # everything below is a global endpoint: study-scoped tokens denied
        if not scope.global_ok:
            self._send(req, 403, "text/plain", b"study-scoped token")
            return

        if path == "/" or path == "/index.html":
            self._send(req, 200, "text/html", self._index_page().encode())
        elif path == "/cluster":
            self._send(req, 200, "text/html", _cluster_page().encode())
        elif path == "/metrics":
            self._send(req, 200, "text/plain; version=0.0.4", self._prometheus().encode())
        elif path == "/api/studies":
            self._send_json(req, 200, self._studies_payload())
        elif path == "/api/cluster/metrics":
            self._send_json(req, 200, self._cluster_metrics())
        else:
            self._send(req, 404, "text/plain", b"not found")

    # -- responses -----------------------------------------------------------

    @staticmethod
    def _send(req, status: int, ctype: str, body: bytes) -> None:
        req.send_response(status)
        req.send_header("Content-Type", ctype)
        req.send_header("Content-Length", str(len(body)))
        req.end_headers()
        req.wfile.write(body)

    @classmethod
    def _send_json(cls, req, status: int, payload: dict) -> None:
        cls._send(
            req, status, "application/json",
            json.dumps(payload, allow_nan=False).encode(),
        )

    # -- endpoint bodies -----------------------------------------------------

    def _delta(self, h: _StudyHandle, query: dict) -> dict:
        since_rev = int((query.get("since_rev") or [-1])[0])
        since_num = int((query.get("since_num") or [-1])[0])
        with h.lock:
            h.poller.poll()  # exactly one get_trials_revision RPC
            rev = h.poller.rev
            if rev == since_rev:
                # unchanged study: no trial data is touched at all
                telemetry.inc("dashboard.delta.idle")
                return {"rev": rev, "idle": True}
            telemetry.inc("dashboard.delta.active")
            payload = h.analytics.delta_rows(since_num)
            payload["rev"] = rev
            payload["idle"] = False
            return payload

    def _studies_payload(self) -> dict:
        studies = []
        for s in self._storage.get_all_studies():
            studies.append(
                {
                    "name": s.study_name,
                    "n_trials": int(s.n_trials),
                    "directions": [d.name.lower() for d in s.directions],
                }
            )
        return {"studies": studies}

    def _cluster_metrics(self) -> dict:
        fn = getattr(self._storage, "get_server_metrics", None)
        metrics = None
        if fn is not None:
            try:
                metrics = fn()
            except Exception:
                metrics = None
        # normalize: sharded storage already returns {"shards": [...]}
        if metrics is None:
            shards: list = []
        elif isinstance(metrics, dict) and "shards" in metrics:
            shards = metrics["shards"]
        else:
            shards = [metrics]
        return jsonable({"n_shards": len(shards), "shards": shards})

    def _prometheus(self) -> str:
        """Telemetry registry as Prometheus text exposition format."""

        def sanitize(name: str) -> str:
            return re.sub(r"[^a-zA-Z0-9_]", "_", name)

        snap = telemetry.snapshot()
        lines = []
        for name, v in snap.get("counters", {}).items():
            metric = f"repro_{sanitize(name)}_total"
            lines.append(f"# TYPE {metric} counter")
            lines.append(f"{metric} {v}")
        for name, v in snap.get("gauges", {}).items():
            metric = f"repro_{sanitize(name)}"
            lines.append(f"# TYPE {metric} gauge")
            lines.append(f"{metric} {v}")
        for name, s in snap.get("histograms", {}).items():
            metric = f"repro_{sanitize(name)}_seconds"
            lines.append(f"# TYPE {metric} summary")
            for q in ("p50", "p95", "p99"):
                lines.append(f'{metric}{{quantile="{q[1:]}"}} {s[q]}')
            lines.append(f"{metric}_sum {s['sum']}")
            lines.append(f"{metric}_count {s['count']}")
        return "\n".join(lines) + "\n"

    def _index_page(self) -> str:
        rows = []
        for s in self._storage.get_all_studies():
            name = html.escape(s.study_name)
            dirs = ", ".join(d.name.lower() for d in s.directions)
            rows.append(
                f'<tr><td><a href="/study/{name}">{name}</a></td>'
                f"<td>{dirs}</td><td>{s.n_trials}</td></tr>"
            )
        body = (
            "<h1>studies</h1>"
            '<table><tr><th>study</th><th>directions</th><th>trials</th></tr>'
            f'{"".join(rows) or "<tr><td colspan=3>none yet</td></tr>"}</table>'
            '<p><a href="/cluster">cluster metrics</a> · '
            '<a href="/metrics">prometheus</a></p>'
        )
        return _PAGE.format(title="studies", body=body, script="")


# ---------------------------------------------------------------------------
# HTML (self-contained, inline JS, repo palette)
# ---------------------------------------------------------------------------

_PAGE = """<!doctype html>
<html><head><meta charset="utf-8"><title>{title}</title>
<style>
body {{ font-family: system-ui, sans-serif; margin: 16px; color: #222; }}
h1, h2 {{ font-weight: 600; }} h1 {{ font-size: 20px; }} h2 {{ font-size: 15px; }}
table {{ border-collapse: collapse; font-size: 12px; }}
td, th {{ border: 1px solid #ccc; padding: 3px 8px; text-align: left; }}
svg {{ background: #fafafa; border: 1px solid #ddd; }}
.grid {{ display: flex; flex-wrap: wrap; gap: 16px; }}
.card {{ min-width: 340px; }}
#status {{ color: #666; font-size: 12px; }}
a {{ color: #3b6fb6; }}
</style></head><body>{body}<script>{script}</script></body></html>
"""

_STUDY_JS = r"""
'use strict';
const NAME = document.body.dataset.study;
const B='#3b6fb6', R='#c0392b', G='#2b8a3e';
let rev = -1, lastNum = -1, rows = [], nViews = -1;
const S = (w,h)=>{const s=document.createElementNS('http://www.w3.org/2000/svg','svg');
  s.setAttribute('width',w);s.setAttribute('height',h);return s;};
function el(svg,tag,attrs){const e=document.createElementNS('http://www.w3.org/2000/svg',tag);
  for(const k in attrs)e.setAttribute(k,attrs[k]);svg.appendChild(e);return e;}
function scale(v,lo,hi,a,b){return hi<=lo?(a+b)/2:a+(v-lo)/(hi-lo)*(b-a);}
function extent(a){let lo=Infinity,hi=-Infinity;for(const v of a){if(v==null)continue;
  if(v<lo)lo=v;if(v>hi)hi=v;}return [lo,hi];}
function axes(svg,W,H,P,xlo,xhi,ylo,yhi){
  el(svg,'line',{x1:P,y1:H-P,x2:W-P,y2:H-P,stroke:'#999'});
  el(svg,'line',{x1:P,y1:P,x2:P,y2:H-P,stroke:'#999'});
  const t=(x,y,s,anc)=>{const e=el(svg,'text',{x:x,y:y,'font-size':9,fill:'#666',
    'text-anchor':anc||'middle'});e.textContent=s;};
  t(P,H-P+12,xlo.toPrecision(3));t(W-P,H-P+12,xhi.toPrecision(3));
  t(P-4,H-P,ylo.toPrecision(3),'end');t(P-4,P+8,yhi.toPrecision(3),'end');}
function drawHistory(div,hist){
  div.innerHTML='';const W=420,H=240,P=36;
  hist.forEach((h,k)=>{
    const svg=S(W,H);div.appendChild(svg);
    const n=h.numbers,v=h.values,b=h.best;
    if(!n.length){return;}
    const [xlo,xhi]=extent(n),[ylo,yhi]=extent(v.concat(b));
    axes(svg,W,H,P,xlo,xhi,ylo,yhi);
    for(let i=0;i<n.length;i++){
      el(svg,'circle',{cx:scale(n[i],xlo,xhi,P,W-P),cy:scale(v[i],ylo,yhi,H-P,P),
        r:2,fill:B,'fill-opacity':0.6});}
    const pts=n.map((x,i)=>scale(x,xlo,xhi,P,W-P)+','+scale(b[i],ylo,yhi,H-P,P)).join(' ');
    el(svg,'polyline',{points:pts,fill:'none',stroke:R,'stroke-width':1.5});
    const lbl=el(svg,'text',{x:W-P,y:P-4,'font-size':10,'text-anchor':'end',fill:'#666'});
    lbl.textContent='objective '+k;});
}
function drawContour(div,c){
  div.innerHTML='';if(!c){div.textContent='needs two parameters';return;}
  const W=420,H=280,P=40,svg=S(W,H);div.appendChild(svg);
  const nx=c.x_edges.length-1,ny=c.y_edges.length-1;
  let lo=Infinity,hi=-Infinity;
  for(const row of c.grid)for(const z of row){if(z==null)continue;if(z<lo)lo=z;if(z>hi)hi=z;}
  for(let r=0;r<ny;r++)for(let q=0;q<nx;q++){
    const z=c.grid[r][q];if(z==null)continue;
    const f=hi<=lo?0.5:(z-lo)/(hi-lo);
    const col='rgb('+Math.round(60+180*f)+','+Math.round(110-60*f)+','+Math.round(200-160*f)+')';
    el(svg,'rect',{x:P+q*(W-2*P)/nx,y:H-P-(r+1)*(H-2*P)/ny,
      width:(W-2*P)/nx+0.5,height:(H-2*P)/ny+0.5,fill:col});}
  axes(svg,W,H,P,c.x_edges[0],c.x_edges[nx],c.y_edges[0],c.y_edges[ny]);
  const t=el(svg,'text',{x:W/2,y:12,'font-size':10,'text-anchor':'middle',fill:'#666'});
  t.textContent=c.x_param+' vs '+c.y_param;}
function drawSlices(div,slices){
  div.innerHTML='';
  for(const s of slices.slice(0,4)){
    const W=220,H=170,P=30,svg=S(W,H);div.appendChild(svg);
    if(!s.x.length)continue;
    const [xlo,xhi]=extent(s.x),[ylo,yhi]=extent(s.z);
    axes(svg,W,H,P,xlo,xhi,ylo,yhi);
    for(let i=0;i<s.x.length;i++)
      el(svg,'circle',{cx:scale(s.x[i],xlo,xhi,P,W-P),cy:scale(s.z[i],ylo,yhi,H-P,P),
        r:1.7,fill:B,'fill-opacity':0.5});
    const bs=s.bins;
    if(bs.centers.length>1){
      const band=bs.centers.map((c,i)=>scale(c,xlo,xhi,P,W-P)+','+scale(bs.hi[i],ylo,yhi,H-P,P))
        .concat(bs.centers.slice().reverse().map((c,i)=>{const j=bs.centers.length-1-i;
          return scale(c,xlo,xhi,P,W-P)+','+scale(bs.lo[j],ylo,yhi,H-P,P);})).join(' ');
      el(svg,'polygon',{points:band,fill:G,'fill-opacity':0.15});
      el(svg,'polyline',{points:bs.centers.map((c,i)=>scale(c,xlo,xhi,P,W-P)+','+
        scale(bs.med[i],ylo,yhi,H-P,P)).join(' '),fill:'none',stroke:G,'stroke-width':1.5});}
    const t=el(svg,'text',{x:W/2,y:11,'font-size':10,'text-anchor':'middle',fill:'#666'});
    t.textContent=s.param;}}
function drawPareto(div,p){
  div.innerHTML='';if(!p){div.textContent='2-objective studies only';return;}
  const W=300,H=240,P=36,svg=S(W,H);div.appendChild(svg);
  if(!p.numbers.length)return;
  const xs=p.values.map(v=>v[0]),ys=p.values.map(v=>v[1]);
  const [xlo,xhi]=extent(xs),[ylo,yhi]=extent(ys);
  axes(svg,W,H,P,xlo,xhi,ylo,yhi);
  const front=new Set(p.front_numbers);
  for(let i=0;i<xs.length;i++){
    const f=front.has(p.numbers[i]);
    el(svg,'circle',{cx:scale(xs[i],xlo,xhi,P,W-P),cy:scale(ys[i],ylo,yhi,H-P,P),
      r:f?3:2,fill:f?R:B,'fill-opacity':f?0.95:0.45});}}
function drawCurves(div,curves){
  div.innerHTML='';
  for(const obj of curves.objectives){
    const W=300,H=200,P=30,svg=S(W,H);div.appendChild(svg);
    const steps=obj.steps,M=obj.matrix;
    if(!steps.length||!M.length)continue;
    let lo=Infinity,hi=-Infinity;
    for(const row of M)for(const v of row){if(v==null)continue;if(v<lo)lo=v;if(v>hi)hi=v;}
    axes(svg,W,H,P,steps[0],steps[steps.length-1],lo,hi);
    for(const row of M){
      const pts=[];
      for(let i=0;i<steps.length;i++)if(row[i]!=null)
        pts.push(scale(steps[i],steps[0],steps[steps.length-1],P,W-P)+','+
          scale(row[i],lo,hi,H-P,P));
      if(pts.length>1)el(svg,'polyline',{points:pts.join(' '),fill:'none',
        stroke:B,'stroke-opacity':0.45,'stroke-width':1});}}}
function drawImportance(div,imp){
  div.innerHTML='';
  for(const k in imp.fanova){
    const d=imp.fanova[k];const names=Object.keys(d);
    if(!names.length)continue;
    const h=document.createElement('div');
    h.innerHTML='<b style="font-size:11px">objective '+k+' (fANOVA)</b>';
    div.appendChild(h);
    for(const n of names){
      const row=document.createElement('div');
      row.style.cssText='display:flex;align-items:center;font-size:11px;gap:6px';
      row.innerHTML='<span style="width:110px;text-align:right">'+n+'</span>'+
        '<span style="display:inline-block;height:10px;background:'+B+';width:'+
        Math.max(1,Math.round(d[n]*180))+'px"></span><span>'+d[n].toFixed(3)+'</span>';
      div.appendChild(row);}}}
function renderTable(){
  const t=document.getElementById('trials');
  const last=rows.slice(-25).reverse();
  let h='<tr><th>#</th><th>state</th><th>values</th><th>params</th></tr>';
  for(const r of last)h+='<tr><td>'+r.number+'</td><td>'+r.state+'</td><td>'+
    r.values.map(v=>v==null?'nan':v.toPrecision(5)).join(', ')+'</td><td>'+
    Object.entries(r.params).map(([k,v])=>k+'='+(typeof v==='number'?v.toPrecision(4):v))
      .join(', ')+'</td></tr>';
  t.innerHTML=h;}
async function refreshViews(){
  const v=await (await fetch('/api/study/'+NAME+'/views')).json();
  drawHistory(document.getElementById('history'),v.history);
  drawContour(document.getElementById('contour'),v.contour);
  drawSlices(document.getElementById('slices'),v.slices);
  drawPareto(document.getElementById('pareto'),v.pareto);
  drawCurves(document.getElementById('curves'),v.curves);
  drawImportance(document.getElementById('importance'),v.importance);
  document.getElementById('meta').textContent=
    v.n_finished+' finished ('+Object.entries(v.by_state).map(([k,n])=>k+':'+n).join(' ')+
    ') · directions: '+v.directions.join(', ');}
async function poll(){
  try{
    const d=await (await fetch('/api/study/'+NAME+'/delta?since_rev='+rev+
      '&since_num='+lastNum)).json();
    if(d.idle){document.getElementById('status').textContent=
      'idle @ rev '+d.rev+' · '+new Date().toLocaleTimeString();}
    else{
      rev=d.rev;lastNum=d.last_number;
      rows=rows.concat(d.rows);renderTable();
      document.getElementById('status').textContent=
        '+'+d.rows.length+' rows @ rev '+d.rev+' · '+new Date().toLocaleTimeString();
      await refreshViews();}
  }catch(e){document.getElementById('status').textContent='poll error: '+e;}
  setTimeout(poll,2000);}
poll();
"""

_CLUSTER_JS = r"""
'use strict';
async function poll(){
  try{
    const m=await (await fetch('/api/cluster/metrics')).json();
    const div=document.getElementById('shards');
    let h='';
    m.shards.forEach((s,i)=>{
      h+='<h2>shard '+i+'</h2><table><tr><th>metric</th><th>value</th></tr>';
      const flat=(obj,pre)=>{for(const k in obj){const v=obj[k];
        if(v&&typeof v==='object'&&!Array.isArray(v))flat(v,pre+k+'.');
        else h+='<tr><td>'+pre+k+'</td><td>'+JSON.stringify(v)+'</td></tr>';}};
      flat(s,'');h+='</table>';});
    div.innerHTML=h||'<p>no server metrics (local storage?)</p>';
    document.getElementById('status').textContent=
      m.n_shards+' shard(s) · '+new Date().toLocaleTimeString();
  }catch(e){document.getElementById('status').textContent='poll error: '+e;}
  setTimeout(poll,3000);}
poll();
"""


def _study_page(name: str) -> str:
    safe = html.escape(name)
    body = (
        f'<h1><a href="/">studies</a> / {safe}</h1>'
        '<p id="meta"></p><p id="status">connecting…</p>'
        '<div class="grid">'
        '<div class="card"><h2>optimization history</h2><div id="history"></div></div>'
        '<div class="card"><h2>contour</h2><div id="contour"></div></div>'
        '<div class="card"><h2>pareto front</h2><div id="pareto"></div></div>'
        '<div class="card"><h2>learning curves</h2><div id="curves"></div></div>'
        '<div class="card"><h2>slices</h2><div id="slices"></div></div>'
        '<div class="card"><h2>importance</h2><div id="importance"></div></div>'
        '</div><h2>recent trials</h2><table id="trials"></table>'
    )
    page = _PAGE.format(title=safe, body=body, script=_STUDY_JS)
    return page.replace("<body>", f'<body data-study="{safe}">')


def _cluster_page() -> str:
    body = (
        '<h1><a href="/">studies</a> / cluster</h1>'
        '<p id="status">connecting…</p><div id="shards"></div>'
    )
    return _PAGE.format(title="cluster", body=body, script=_CLUSTER_JS)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.serve.dashboard_service",
        description="live analytics dashboard over any storage URL",
    )
    ap.add_argument("--storage", required=True, help="storage URL (remote://, sqlite://, …)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--token", action="append", default=None,
                    help="auth token (repeatable; omit for open access)")
    args = ap.parse_args(argv)
    telemetry.enable()
    svc = DashboardService(
        args.storage, host=args.host, port=args.port, tokens=args.token
    ).start()
    print(f"dashboard: {svc.url}", flush=True)
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        svc.stop()


if __name__ == "__main__":
    main()
