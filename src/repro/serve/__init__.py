from __future__ import annotations

from .engine import Engine, make_decode_step, make_prefill_step, sample_token

__all__ = ["Engine", "make_prefill_step", "make_decode_step", "sample_token"]
