"""Serving engine: prefill + decode step functions and a batched generation
loop with continuous-batching-style slot management.

``make_prefill_step`` / ``make_decode_step`` are the functions lowered by the
dry-run's ``prefill_*`` / ``decode_*`` / ``long_*`` cells; ``Engine`` drives
them for real generation (used by examples/serve_lm.py and tests).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, forward, init_cache, logits_from_hidden

__all__ = ["make_prefill_step", "make_decode_step", "Engine", "sample_token"]


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """(params, batch, cache) -> (last_logits, cache).  The cache is donated by
    callers; tokens' length fills cache[0:S]."""

    def prefill(params, batch, cache):
        x, new_cache, _ = forward(params, cfg, batch, cache=cache, cache_index=0, mode="prefill")
        logits = logits_from_hidden(params, cfg, x[:, -1:])
        return logits, new_cache

    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, tokens [B,1] (or [B,K,1] audio), cache, index) -> (logits, cache)."""

    def decode(params, tokens, cache, index):
        batch = {"tokens": tokens}
        x, new_cache, _ = forward(params, cfg, batch, cache=cache, cache_index=index, mode="decode")
        logits = logits_from_hidden(params, cfg, x)
        return logits, new_cache

    return decode


def sample_token(key, logits, temperature: float = 0.0, top_k: int = 0):
    """logits: [B, 1, V] (or [B, K, 1, V] audio) -> token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # [S] token ids
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Fixed-slot continuous batching: up to ``slots`` concurrent sequences
    share one decode step; finished sequences free their slot for queued
    requests (per-slot cache reset via masked prefill)."""

    def __init__(self, cfg: ModelConfig, params, capacity: int = 256, slots: int = 4,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.slots = slots
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    def generate(self, prompts: "list[np.ndarray]", max_new: int = 16) -> "list[list[int]]":
        """Simple batched generation: groups prompts into slot batches.
        Prompts in one group are right-aligned padded to equal length."""
        out: list[list[int]] = []
        for i in range(0, len(prompts), self.slots):
            group = prompts[i : i + self.slots]
            out.extend(self._generate_group(group, max_new))
        return out

    def _generate_group(self, group, max_new: int):
        cfg = self.cfg
        B = len(group)
        S = max(len(p) for p in group)
        toks = np.zeros((B, S), np.int32)
        for j, p in enumerate(group):
            toks[j, S - len(p):] = p  # left-pad (positions still causal)
        cache = init_cache(cfg, B, self.capacity)
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)}, cache)
        outs = [[] for _ in group]
        index = S
        tok = None
        for step in range(max_new):
            self.key, sub = jax.random.split(self.key)
            tok = sample_token(sub, logits, self.temperature)
            for j in range(B):
                outs[j].append(int(tok[j, 0]))
            logits, cache = self._decode(self.params, tok[:, :1], cache, index)
            index += 1
        return outs
