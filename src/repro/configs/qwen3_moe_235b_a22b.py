"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 (hf:Qwen/Qwen3-30B-A3B
family scaled per assignment).

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936, top-8 of 128.
Adafactor optimizer so the 235B-parameter optimizer state fits a single
256-chip pod (see DESIGN.md §Dtype/optimizer policy).
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        vocab=151936,
        superblock=(BlockDef(kind="attn", ffn="moe"),),
        n_superblocks=94,
        moe_experts=128,
        moe_top_k=8,
        moe_d_ff=1536,
        moe_norm_topk=True,
        rope_theta=1000000.0,
        optimizer="adafactor",
        train_microbatch=8,  # shrinks the layer-scan residual stack (EXPERIMENTS.md §Dry-run)
        serve_fsdp=True,  # 470 GB of bf16 weights need the batch axes too
        # §Perf iteration 3: 64 q-heads shard 16-way (Megatron attention);
        # k/v (4 heads) replicate cheaply. collective -22%, memory -15%.
        attn_head_shard=True,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab=256,
        superblock=(BlockDef(kind="attn", ffn="moe"),),
        n_superblocks=2,
        moe_experts=8,
        moe_top_k=2,
        moe_d_ff=96,
        moe_group=64,
        rope_theta=1000000.0,
        optimizer="adafactor",
        q_chunk=16,
        ce_chunk=16,
    )
