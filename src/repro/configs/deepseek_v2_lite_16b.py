"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed experts
top-6 (arXiv:2405.04434).

27L d_model=2048 16H, expert d_ff=1408, vocab=102400.  Layer 0 is a dense
SwiGLU layer (d_ff=10944) as in the released model; layers 1..26 are MLA+MoE.
(The assignment note "160 routed" matches DeepSeek-V2-full; -lite has 64
routed experts, which we follow per the primary config line.)
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=102400,
        head_blocks=(BlockDef(kind="mla", ffn="swiglu", d_ff=10944),),
        superblock=(BlockDef(kind="mla", ffn="moe"),),
        n_superblocks=26,
        moe_experts=64,
        moe_top_k=6,
        moe_d_ff=1408,
        moe_shared_d_ff=2816,  # 2 shared experts x 1408
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        rope_theta=10000.0,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab=256,
        head_blocks=(BlockDef(kind="mla", ffn="swiglu", d_ff=192),),
        superblock=(BlockDef(kind="mla", ffn="moe"),),
        n_superblocks=2,
        moe_experts=8,
        moe_top_k=2,
        moe_d_ff=96,
        moe_shared_d_ff=96,
        moe_group=64,
        kv_lora_rank=32,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        q_chunk=16,
        ce_chunk=16,
    )
