"""internlm2-1.8b [dense] — GQA (arXiv:2403.17297).

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92544,
        superblock=(BlockDef(kind="attn"),),
        n_superblocks=24,
        rope_theta=1000000.0,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab=384,
        superblock=(BlockDef(kind="attn"),),
        n_superblocks=2,
        rope_theta=1000000.0,
        q_chunk=16,
        ce_chunk=16,
    )
