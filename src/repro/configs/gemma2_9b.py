"""gemma2-9b [dense] — local+global alternating attention, logit softcaps
(arXiv:2408.00118).

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000; sliding window 4096
on the local layers, attn softcap 50, final softcap 30, sandwich norms,
sqrt(d)-scaled embeddings, tied LM head (the 256k vocab dominates memory).
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        superblock=(
            BlockDef(kind="attn", window=4096, ffn="geglu", post_norms=True),
            BlockDef(kind="attn", window=-1, ffn="geglu", post_norms=True),
        ),
        n_superblocks=21,
        attn_softcap=50.0,
        final_softcap=30.0,
        embed_scale=True,
        tie_embeddings=True,
        rope_theta=10000.0,
        ce_chunk=128,  # 256k vocab: keep the CE chunk buffer small
        # §Perf iteration 1: q_chunk must divide the sequence-parallel shard
        # (4096/16 = 256) or every chunk straddles two shards and GSPMD emits
        # pairwise reshard collectives (measured: -34% collective bytes)
        q_chunk=256,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        superblock=(
            BlockDef(kind="attn", window=8, ffn="geglu", post_norms=True),
            BlockDef(kind="attn", window=-1, ffn="geglu", post_norms=True),
        ),
        n_superblocks=2,
        attn_softcap=50.0,
        final_softcap=30.0,
        embed_scale=True,
        tie_embeddings=True,
        q_chunk=16,
        ce_chunk=16,
    )
