"""tinyllama-1.1b [dense] — llama2-arch small (arXiv:2401.02385).

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        n_layers=22,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=5632,
        vocab=32000,
        superblock=(BlockDef(kind="attn"),),
        n_superblocks=22,
        rope_theta=10000.0,
        tie_embeddings=False,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        superblock=(BlockDef(kind="attn"),),
        n_superblocks=2,
        q_chunk=16,
        ce_chunk=16,
    )
