"""llava-next-34b [vlm] — anyres tiling; transformer backbone only
(hf:llava-hf/llava-v1.6 family).

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision tower is
a STUB per the assignment: ``input_specs()`` supplies precomputed anyres
patch embeddings [B, img_tokens, d_model]; the backbone concatenates them
ahead of the text tokens and masks them out of the loss.
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab=64000,
        superblock=(BlockDef(kind="attn"),),
        n_superblocks=60,
        modality="vlm",
        img_tokens=1152,  # anyres: base 576 + one 576-patch tile
        rope_theta=5000000.0,
        train_microbatch=2,  # halve the d=7168 residual stack (EXPERIMENTS.md §Dry-run)
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        superblock=(BlockDef(kind="attn"),),
        n_superblocks=2,
        modality="vlm",
        img_tokens=8,
        q_chunk=16,
        ce_chunk=16,
    )
