"""musicgen-medium [audio] — decoder-only over EnCodec tokens
(arXiv:2306.05284).

48L d_model=1536 24H (MHA kv=24) d_ff=6144, 4 codebooks x vocab 2048 with the
delay interleaving pattern applied by the (stubbed) EnCodec frontend; the
model sums the 4 codebook embeddings and predicts 4 parallel heads.
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab=2048,
        superblock=(BlockDef(kind="attn", ffn="gelu"),),
        n_superblocks=48,
        modality="audio",
        num_codebooks=4,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=128,
        superblock=(BlockDef(kind="attn", ffn="gelu"),),
        n_superblocks=2,
        modality="audio",
        num_codebooks=2,
        q_chunk=16,
        ce_chunk=16,
    )
