"""smollm-135m [dense] — llama-arch small (hf:HuggingFaceTB/SmolLM-135M).

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab=49152,
        superblock=(BlockDef(kind="attn"),),
        n_superblocks=30,
        tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke",
        n_layers=3,
        d_model=48,
        n_heads=3,
        n_kv_heads=3,
        d_ff=96,
        vocab=256,
        superblock=(BlockDef(kind="attn"),),
        n_superblocks=3,
        tie_embeddings=True,
        q_chunk=16,
        ce_chunk=16,
    )
