"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks
(arXiv:2411.15242).

38L d_model=2048, ssm_state=64; the assigned 32H/kv=32 and d_ff=8192 describe
the *shared* transformer block that is interleaved (same weights every time)
after every 6 mamba2 layers.  38 = 6x6 scanned + 2 tail mamba layers.
Sub-quadratic backbone: designated long_500k arch.
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32000,
        superblock=(
            *(BlockDef(kind="mamba2", ffn="none"),) * 6,
            BlockDef(kind="attn", shared=True),
        ),
        n_superblocks=6,
        tail_blocks=(
            BlockDef(kind="mamba2", ffn="none"),
            BlockDef(kind="mamba2", ffn="none"),
        ),
        has_shared_block=True,
        shared_block=BlockDef(kind="attn", ffn="swiglu"),
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        superblock=(
            BlockDef(kind="mamba2", ffn="none"),
            BlockDef(kind="mamba2", ffn="none"),
            BlockDef(kind="attn", shared=True),
        ),
        n_superblocks=2,
        tail_blocks=(BlockDef(kind="mamba2", ffn="none"),),
        has_shared_block=True,
        shared_block=BlockDef(kind="attn", ffn="swiglu"),
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=16,
        ssm_chunk=16,
        tie_embeddings=True,
        q_chunk=16,
        ce_chunk=16,
    )
