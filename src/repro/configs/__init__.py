"""Architecture configs (``--arch <id>``): exact assigned hyperparameters.

Each module exports ``get_config()`` (the full production config) and
``get_smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCHS = [
    "tinyllama_1_1b",
    "gemma2_9b",
    "internlm2_1_8b",
    "smollm_135m",
    "xlstm_1_3b",
    "zamba2_1_2b",
    "deepseek_v2_lite_16b",
    "qwen3_moe_235b_a22b",
    "llava_next_34b",
    "musicgen_medium",
]

# canonical ids as assigned (hyphens/dots) -> module names
ARCH_IDS = {
    "tinyllama-1.1b": "tinyllama_1_1b",
    "gemma2-9b": "gemma2_9b",
    "internlm2-1.8b": "internlm2_1_8b",
    "smollm-135m": "smollm_135m",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llava-next-34b": "llava_next_34b",
    "musicgen-medium": "musicgen_medium",
}

# shape cells skipped per arch (see DESIGN.md §Arch-applicability):
# long_500k requires sub-quadratic context handling; pure full-attention
# archs are skipped per the assignment brief.
LONG_CONTEXT_ARCHS = {"xlstm-1.3b", "zamba2-1.2b", "gemma2-9b"}


def get_config(arch: str):
    mod = ARCH_IDS.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}").get_config()


def get_smoke_config(arch: str):
    mod = ARCH_IDS.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}").get_smoke_config()


def cells(arch: str) -> list[str]:
    """Shape names that apply to this arch (40-cell table minus documented skips)."""
    from repro.models.config import SHAPES

    out = []
    for name in SHAPES:
        if name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
            continue
        out.append(name)
    return out
