"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

48L d_model=2048 4H d_ff=0 vocab=50304.  d_ff=0 means the blocks are
projection blocks (mLSTM proj-factor 2) with no separate FFN; pattern is
7 mLSTM : 1 sLSTM per superblock (48 = 6 x 8).  Sub-quadratic: designated
long_500k arch (recurrent O(1)-state decode).
"""

from repro.models.config import BlockDef, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        superblock=(
            *(BlockDef(kind="mlstm", ffn="none"),) * 7,
            BlockDef(kind="slstm", ffn="none"),
        ),
        n_superblocks=6,
        ssm_proj_factor=2,
        tie_embeddings=False,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        n_layers=4,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab=256,
        superblock=(
            BlockDef(kind="mlstm", ffn="none"),
            BlockDef(kind="slstm", ffn="none"),
        ),
        n_superblocks=2,
        ssm_proj_factor=2,
        q_chunk=16,
        ce_chunk=16,
    )
