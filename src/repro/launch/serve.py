"""Serving launcher: batched generation with the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --requests 8 --max-new 32
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--checkpoint", default=None, help="restore params from this .ckpt")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro import configs
    from repro.models import abstract_params, init_model_params
    from repro.serve import Engine
    from repro.train import restore_pytree

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    if args.checkpoint:
        _, params = restore_pytree(args.checkpoint, abstract_params(cfg))
    else:
        params = init_model_params(cfg, jax.random.PRNGKey(0))

    engine = Engine(cfg, params, capacity=args.capacity, slots=args.slots,
                    temperature=args.temperature)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=rng.randint(4, 17)).astype(np.int32)
               for _ in range(args.requests)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    print(f"[serve] {n} tokens / {dt:.2f}s = {n/dt:.1f} tok/s "
          f"({args.requests} requests, {args.slots} slots)")


if __name__ == "__main__":
    main()
