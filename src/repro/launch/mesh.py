"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS *before* any jax initialization; smoke tests must
keep seeing 1 device).
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_host_mesh", "slice_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16) ("data","model") = 256 chips (v5e pod).
    Multi-pod: (2,16,16) ("pod","data","model") = 512 chips; "pod" is a batch
    axis crossing the DCN/inter-pod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over host (CPU) devices for tests."""
    return jax.make_mesh(shape, axes)


def slice_mesh(mesh, n_slices: int, axis: str = "data"):
    """Split a mesh into ``n_slices`` disjoint sub-meshes along ``axis`` —
    trial-parallel HPO: each concurrent trial trains on one slice (see
    repro.tune.scheduler).  Returns a list of Mesh objects over disjoint
    device subsets."""
    from jax.sharding import Mesh

    devs = mesh.devices  # ndarray [axes...]
    ax = mesh.axis_names.index(axis)
    size = devs.shape[ax]
    assert size % n_slices == 0, (size, n_slices)
    chunk = size // n_slices
    out = []
    for i in range(n_slices):
        sl = [slice(None)] * devs.ndim
        sl[ax] = slice(i * chunk, (i + 1) * chunk)
        out.append(Mesh(devs[tuple(sl)], mesh.axis_names))
    return out
