"""§Perf hillclimb driver: lower + compile one cell under config overrides and
report the three roofline terms — the measurement half of the
hypothesis -> change -> measure -> validate loop.

    PYTHONPATH=src python -m repro.launch.perf_compare --arch gemma2-9b \
        --shape train_4k --set bf16_weight_gather=False --set moe_group=512
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time

import jax


def measure(arch: str, shape: str, overrides: dict, multi_pod: bool = False) -> dict:
    from repro import configs
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS
    from repro.launch.specs import build_step

    cfg = configs.get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_step(cfg, shape, mesh)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(
            cell.step, in_shardings=cell.in_shardings, donate_argnums=cell.donate
        ).lower(*cell.args).compile()
    st = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "arch": arch,
        "shape": shape,
        "overrides": {k: str(v) for k, v in overrides.items()},
        "compile_s": round(time.time() - t0, 1),
        "t_compute_s": st.flops / PEAK_FLOPS,
        "t_memory_s": st.bytes_accessed / HBM_BW,
        "t_collective_s": st.collective_bytes / LINK_BW,
        "collectives": {k: v for k, v in st.collectives.items()},
        "mem_per_dev_gib": (
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes
        ) / 2**30,
        "flops": st.flops,
        "bytes": st.bytes_accessed,
        "collective_bytes": st.collective_bytes,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[], help="field=value overrides")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        overrides[k] = v

    r = measure(args.arch, args.shape, overrides, args.multi_pod)
    if args.json:
        print(json.dumps(r, indent=1))
    else:
        print(
            f"{args.arch} {args.shape} {overrides or 'baseline-config'}\n"
            f"  compute   {r['t_compute_s']:10.4f} s  ({r['flops']:.3e} flops/dev)\n"
            f"  memory    {r['t_memory_s']:10.4f} s  ({r['bytes']:.3e} B/dev)\n"
            f"  collective{r['t_collective_s']:10.4f} s  ({r['collective_bytes']:.3e} B/dev)"
            f"  {({k: f'{v:.2e}' for k, v in r['collectives'].items()})}\n"
            f"  mem/dev   {r['mem_per_dev_gib']:10.2f} GiB   compile {r['compile_s']}s"
        )


if __name__ == "__main__":
    main()
