"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost/collective accounting — proof that the distribution config
is coherent without real hardware.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Outputs one JSON per cell under results/dryrun/.
"""

# The host has ONE real CPU device; the dry-run needs 512 placeholder devices
# so jax.make_mesh can build the production meshes.  These two lines MUST run
# before any other import (jax locks the device count on first init).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_step
from repro.models import count_active_params, count_params

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str, verbose: bool = True) -> dict:
    cfg = configs.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    cell = build_step(cfg, shape, mesh)
    with mesh:
        jitted = jax.jit(
            cell.step, in_shardings=cell.in_shardings, donate_argnums=cell.donate
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax returns [dict] per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo)

    n_chips = mesh.devices.size
    record = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "n_chips": int(n_chips),
        "params": count_params(cfg),
        "active_params": count_active_params(cfg),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes
            + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "xla_cost_analysis": {
            k: v for k, v in cost.items() if k in ("flops", "bytes accessed")
        },
        "hlo_stats": stats.asdict(),
        "hlo_bytes": len(hlo),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        print(
            f"[dryrun] OK {arch:24s} {shape:12s} {mesh_name:10s} "
            f"compile={t_compile:6.1f}s mem/dev={record['memory']['per_device_total']/2**30:7.2f}GiB "
            f"flops={stats.flops:.3e} coll={stats.collective_bytes:.3e}B",
            flush=True,
        )
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        archs = list(configs.ARCH_IDS)
    elif args.arch:
        archs = [args.arch]
    else:
        ap.error("--arch or --all required")

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        shapes = [args.shape] if args.shape else configs.cells(arch)
        for shape in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] skip {arch} {shape} {mesh_name} (exists)", flush=True)
                    continue
                try:
                    run_cell(arch, shape, multi, args.out)
                except Exception as e:
                    failures.append((arch, shape, mesh_name, repr(e)))
                    print(f"[dryrun] FAIL {arch} {shape} {mesh_name}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        return 1
    print("\nall dry-run cells passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
