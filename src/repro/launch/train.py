"""Production train launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 100 --batch 8 --seq 128 --workdir /ckpt/run1

On TPU pods, run once per host (JAX distributed init is picked up from the
TPU environment); on CPU it runs single-process with any smoke-scale config.
Auto-resumes from the newest checkpoint in --workdir; SIGTERM checkpoints
and exits cleanly (preemption-safe).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--data", default=None, help="packed int32 token file (memmap)")
    args = ap.parse_args()

    from repro import configs
    from repro.models import count_params
    from repro.train import TrainConfig, Trainer, make_data

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    print(f"[train] {cfg.name}: {count_params(cfg)/1e6:.1f}M params")
    tcfg = TrainConfig(
        lr=args.lr,
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
        eval_every=max(args.steps // 20, 1),
        checkpoint_every=max(args.steps // 4, 1),
        microbatch=args.microbatch,
    )
    data = make_data(cfg, args.batch, args.seq, path=args.data)
    result = Trainer(cfg, tcfg, data, workdir=args.workdir).run()
    print(f"[train] done at step {result['step']}; losses: "
          + " ".join(f"{l:.3f}" for l in result.get("losses", [])))


if __name__ == "__main__":
    main()
