"""Abstract input/step construction shared by the dry-run and the real
launchers: ``input_specs`` (ShapeDtypeStruct stand-ins for every model input)
and ``build_step`` (the jitted step with in/out shardings for a given cell).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import (
    ModelConfig,
    SHAPES,
    abstract_params,
    cache_logical,
    init_cache,
    params_logical,
)
from repro.models.sharding import (
    SERVE_RULES,
    TRAIN_RULES,
    ShardingRules,
    logical_to_sharding,
    tree_shardings,
    wrap_with_sharding_ctx,
)
from repro.serve import make_decode_step, make_prefill_step
from repro.train.optimizer import Optimizer
from repro.train.train_loop import TrainConfig, make_optimizer_for, make_train_step, _opt_shardings

__all__ = ["input_specs", "build_step", "Cell"]

_BATCH_LOGICAL = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "image_embeds": ("batch", "seq", "embed"),
}


def _batch_abstract(cfg: ModelConfig, batch: int, seq: int) -> dict:
    i32 = jnp.int32
    if cfg.modality == "audio":
        return {
            "tokens": jax.ShapeDtypeStruct((batch, cfg.num_codebooks, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, cfg.num_codebooks, seq), i32),
        }
    if cfg.modality == "vlm":
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq - cfg.img_tokens), i32),
            "image_embeds": jax.ShapeDtypeStruct(
                (batch, cfg.img_tokens, cfg.d_model), jnp.bfloat16
            ),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }


def _batch_shardings(batch_abs: dict, mesh, rules: ShardingRules):
    def one(name, s):
        if name == "image_embeds":
            logical = ("batch", None, None)
        elif len(s.shape) == 3:  # audio [B, K, S]
            logical = ("batch", None, "seq")
        else:
            logical = ("batch", "seq")
        return logical_to_sharding(logical, s.shape, mesh, rules)

    return {k: one(k, v) for k, v in batch_abs.items()}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the cell's step function
    (weak-type-correct, shardable, no device allocation)."""
    shp = SHAPES[shape_name]
    if shp.kind == "train":
        return _batch_abstract(cfg, shp.global_batch, shp.seq_len)
    if shp.kind == "prefill":
        return _batch_abstract(cfg, shp.global_batch, shp.seq_len)
    # decode: one new token against a seq_len cache
    i32 = jnp.int32
    if cfg.modality == "audio":
        toks = jax.ShapeDtypeStruct((shp.global_batch, cfg.num_codebooks, 1), i32)
    else:
        toks = jax.ShapeDtypeStruct((shp.global_batch, 1), i32)
    return {"tokens": toks}


@dataclasses.dataclass
class Cell:
    """One (arch x shape x mesh) dry-run unit: a step fn + fully-specified
    abstract args + shardings, ready to ``jit(...).lower(...)``."""

    name: str
    step: Callable
    args: tuple
    in_shardings: tuple
    donate: tuple = ()


def build_step(cfg: ModelConfig, shape_name: str, mesh, tcfg: TrainConfig | None = None) -> Cell:
    shp = SHAPES[shape_name]
    aps = abstract_params(cfg)
    p_logical = params_logical(cfg)

    if shp.kind == "train":
        rules = TRAIN_RULES
        p_sh = tree_shardings(aps, p_logical, mesh, rules)
        opt = make_optimizer_for(cfg, tcfg or TrainConfig())
        opt_abs = jax.eval_shape(opt.init, aps)
        o_sh = _opt_shardings(opt_abs, p_sh)
        batch_abs = input_specs(cfg, shape_name)
        b_sh = _batch_shardings(batch_abs, mesh, rules)
        step = wrap_with_sharding_ctx(
            make_train_step(cfg, opt, cfg.train_microbatch), mesh, rules
        )
        scalar = jax.ShapeDtypeStruct((), jnp.int32)
        scalar_sh = NamedSharding(mesh, PartitionSpec())
        return Cell(
            name=f"{cfg.name}:{shape_name}",
            step=step,
            args=(aps, opt_abs, scalar, batch_abs),
            in_shardings=(p_sh, o_sh, scalar_sh, b_sh),
            donate=(0, 1),
        )

    rules = SERVE_RULES
    if cfg.serve_fsdp:
        rules = ShardingRules({**SERVE_RULES.rules, "fsdp_embed": ("pod", "data")})
    # serving runs on bf16 weights (f32 masters stay in the checkpoint)
    sdt = jnp.dtype(cfg.serve_param_dtype)
    aps = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, sdt), aps)
    p_sh = tree_shardings(aps, p_logical, mesh, rules)
    cache_abs = jax.eval_shape(
        lambda: init_cache(cfg, shp.global_batch, shp.seq_len, jnp.bfloat16)
    )
    c_logical = cache_logical(cache_abs)
    c_sh = tree_shardings(cache_abs, c_logical, mesh, rules)

    if shp.kind == "prefill":
        batch_abs = input_specs(cfg, shape_name)
        b_sh = _batch_shardings(batch_abs, mesh, rules)
        step = wrap_with_sharding_ctx(make_prefill_step(cfg), mesh, rules)
        return Cell(
            name=f"{cfg.name}:{shape_name}",
            step=step,
            args=(aps, batch_abs, cache_abs),
            in_shardings=(p_sh, b_sh, c_sh),
            donate=(2,),
        )

    # decode
    tok_abs = input_specs(cfg, shape_name)["tokens"]
    tok_logical = ("batch", None, None)[: len(tok_abs.shape)]
    t_sh = logical_to_sharding(tok_logical, tok_abs.shape, mesh, rules)
    idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
    idx_sh = NamedSharding(mesh, PartitionSpec())
    step = wrap_with_sharding_ctx(make_decode_step(cfg), mesh, rules)
    return Cell(
        name=f"{cfg.name}:{shape_name}",
        step=step,
        args=(aps, tok_abs, cache_abs, idx_abs),
        in_shardings=(p_sh, t_sh, c_sh, idx_sh),
        donate=(2,),
    )
