"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds per step, per chip — the analyzer's totals are per-partition):

  compute    = HLO_FLOPs_per_chip / peak_FLOPs
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

The bottleneck is max(terms); the reported *roofline fraction* is
useful-model-FLOPs MFU at the modeled step time:

  MODEL_FLOPS/chips/peak / max(terms)

MODEL_FLOPS uses 6·N·D for training (N = active matmul params; D = tokens)
and 2·N·D for prefill/decode.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

__all__ = ["roofline_row", "load_all", "format_table", "PEAK_FLOPS", "HBM_BW", "LINK_BW"]


def _model_flops(record: dict) -> float:
    from repro import configs
    from repro.models import SHAPES, count_active_params, param_specs
    from repro.models.layers import Spec
    import jax, math

    cfg = configs.get_config(record["arch"])
    shp = SHAPES[record["shape"]]
    # matmul-active params: exclude the embedding lookup table (gather), keep
    # the LM head (tied embeds are used as a matmul there: count once)
    n_active = count_active_params(cfg)
    specs = param_specs(cfg)
    if "embed" in specs and not cfg.tie_embeddings:
        n_active -= math.prod(specs["embed"].shape)
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n_active * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n_active * tokens
    tokens = shp.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def roofline_row(record: dict) -> dict:
    chips = record["n_chips"]
    st = record["hlo_stats"]
    t_compute = st["flops"] / PEAK_FLOPS
    t_memory = st["bytes_accessed"] / HBM_BW
    t_coll = st["collective_bytes"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values()) or 1e-12

    mf = _model_flops(record)
    useful_mfu_at_roofline = (mf / chips / PEAK_FLOPS) / step_time
    flops_ratio = mf / max(st["flops"] * chips, 1e-9)

    return {
        "arch": record["arch"],
        "shape": record["shape"],
        "mesh": record["mesh"],
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_flops_ratio": flops_ratio,  # MODEL_FLOPS / (HLO_FLOPS*chips)
        "roofline_fraction": useful_mfu_at_roofline,
        "mem_per_dev_gib": record["memory"]["per_device_total"] / 2**30,
        "collectives": st.get("collectives", {}),
    }


def load_all(results_dir: str) -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(roofline_row(json.load(f)))
    return rows


def format_table(rows: list, mesh: str | None = "pod16x16") -> str:
    sel = [r for r in rows if mesh is None or r["mesh"] == mesh]
    hdr = (
        f"{'arch':24s} {'shape':12s} {'mesh':10s} {'compute_s':>10s} {'memory_s':>10s} "
        f"{'collect_s':>10s} {'bound':>9s} {'useful':>7s} {'roofline':>9s} {'GiB/dev':>8s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in sel:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
            f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} {r['t_collective_s']:10.4f} "
            f"{r['bottleneck']:>9s} {r['useful_flops_ratio']:7.2f} "
            f"{r['roofline_fraction']:9.3f} {r['mem_per_dev_gib']:8.2f}"
        )
    return "\n".join(lines)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load_all(os.path.abspath(args.results))
    print(format_table(rows, args.mesh))


if __name__ == "__main__":
    main()
