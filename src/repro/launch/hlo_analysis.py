"""Post-optimization HLO accounting for the roofline analysis.

``compiled.cost_analysis()`` counts while-loop (scan) bodies ONCE (verified
empirically — a scan of 10 matmuls reports the FLOPs of 1), and it reports no
collective traffic.  Since every model here scans over layers, we do our own
accounting over ``compiled.as_text()``:

1. split the module into computations and build a per-computation symbol
   table (%name -> shape) — scheduled HLO prints operands without types,
2. build the call graph with *multiplicities*: while bodies multiply by the
   loop trip count (parsed from the loop condition's comparison constant),
   fusions/calls inherit the caller's multiplicity,
3. tally, weighted by multiplicity:
   * FLOPs — ``dot``/``convolution`` ops (2*prod(result)*prod(contracted)),
     counted inside fusions too,
   * HBM bytes — operand+result bytes of materializing ops (fusion
     boundaries and unfused top-level ops; fused-computation internals are
     registers/VMEM),
   * collective bytes per kind (all-reduce / all-gather / reduce-scatter /
     all-to-all / collective-permute), max(operand, result) bytes per op.

Totals are per-device (SPMD modules are compiled per-partition): multiply by
n_chips for whole-fleet numbers, or use directly for per-chip roofline terms.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"^(\w+)\[([\d,]*)\]")
_OPND_RE = re.compile(r"%([\w\.\-]+)")


def _parse_type(rest: str):
    """Parse the type at the start of an instruction RHS.  Returns
    (list of (dtype, dims) for array components, remainder string)."""
    rest = rest.strip()
    if rest.startswith("("):  # tuple type
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    inner = rest[1:i]
                    comps = []
                    for part in inner.split(","):
                        m = _SHAPE_RE.match(part.strip())
                        if m and m.group(1) in _DTYPE_BYTES:
                            comps.append((m.group(1), _dims(m.group(2))))
                    return comps, rest[i + 1:]
        return [], rest
    m = _SHAPE_RE.match(rest)
    if m and m.group(1) in _DTYPE_BYTES:
        end = m.end()
        # skip layout annotation {...}
        rem = rest[end:]
        if rem.startswith("{"):
            close = rem.find("}")
            rem = rem[close + 1:]
        return [(m.group(1), _dims(m.group(2)))], rem
    return [], rest


def _dims(s: str):
    return [int(d) for d in s.split(",")] if s.strip() else []


def _nbytes(comps) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims or [1]) for dt, dims in comps)


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    trip_counts: dict = dataclasses.field(default_factory=dict)
    n_collective_ops: float = 0.0
    dot_flops_by_comp: dict = dataclasses.field(default_factory=dict)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


class _Instr:
    __slots__ = ("name", "op", "shapes", "operands", "line")

    def __init__(self, name, op, shapes, operands, line):
        self.name = name
        self.op = op
        self.shapes = shapes  # [(dtype, dims)...] of the result
        self.operands = operands  # operand %names
        self.line = line


_OP_RE = re.compile(r"^([\w\-]+)\(")


def _split_computations(hlo: str):
    comps: dict[str, list[_Instr]] = {}
    cur = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line.strip():
            continue
        if (
            not line.startswith(" ")
            and line.rstrip().endswith("{")
            and (line.startswith("ENTRY") or line.startswith("%") or " -> " in line)
            and not line.startswith("HloModule")
        ):
            hdr = line.strip()
            is_entry = hdr.startswith("ENTRY")
            hdr = hdr[5:].strip() if is_entry else hdr
            m = re.match(r"%?([\w\.\-]+)", hdr)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if is_entry:
                    entry = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        shapes, rem = _parse_type(rest)
        rem = rem.strip()
        om = _OP_RE.match(rem)
        if not om:
            continue
        op = om.group(1)
        # operands: %names inside the first (...) group
        paren = rem[om.end() - 1:]
        depth = 0
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPND_RE.findall(paren[: end + 1])
        comps[cur].append(_Instr(name, op, shapes, operands, rem))
    return comps, entry


def _trip_count(cond: list) -> int:
    best = 1
    for ins in cond:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(hlo: str) -> HloStats:
    comps, entry = _split_computations(hlo)
    if entry is None and comps:
        entry = next(iter(comps))

    # symbol tables per computation
    symtab: dict[str, dict] = {
        c: {ins.name: ins.shapes for ins in instrs} for c, instrs in comps.items()
    }

    # -- multiplicities ---------------------------------------------------------
    mult: dict[str, float] = defaultdict(float)
    fused: set = set()
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        m = mult[comp]
        for ins in comps.get(comp, []):
            if ins.op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trip = _trip_count(comps.get(cond, [])) if cond in comps else 1
                if body in comps:
                    mult[body] += m * trip
                    if body not in seen:
                        seen.add(body)
                        order.append(body)
            else:
                for attr in ("calls", "to_apply"):
                    am = re.search(attr + r"=%?([\w\.\-]+)", ins.line)
                    if am and am.group(1) in comps:
                        c = am.group(1)
                        mult[c] += m
                        if ins.op == "fusion":
                            fused.add(c)
                        if c not in seen:
                            seen.add(c)
                            order.append(c)
                bm = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                if bm:
                    for c in _OPND_RE.findall(bm.group(1)):
                        if c in comps:
                            mult[c] += m
                            if c not in seen:
                                seen.add(c)
                                order.append(c)

    stats = HloStats()
    per_kind: dict[str, float] = defaultdict(float)

    for comp, instrs in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        tab = symtab[comp]
        in_fused = comp in fused
        comp_dot_flops = 0.0
        for ins in instrs:
            op = ins.op
            if op == "custom-call" and re.search(
                r'custom_call_target="[^"]*(matmul|gemm|dot)[^"]*"', ins.line, re.I
            ):
                # CPU backend lowers some (esp. f32) matmuls to oneDNN custom
                # calls: flops = 2 * prod(result) * contracted (lhs last dim)
                res = ins.shapes
                lhs = tab.get(ins.operands[0]) if ins.operands else None
                if res and lhs and lhs[0][1]:
                    k = lhs[0][1][-1]
                    f = m * 2.0 * math.prod(res[0][1] or [1]) * k
                    stats.flops += f
                    comp_dot_flops += f
                continue
            if op in ("dot", "convolution"):
                res = ins.shapes
                lhs = tab.get(ins.operands[0]) if ins.operands else None
                k = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
                if cm and lhs:
                    for d in _dims(cm.group(1)):
                        if d < len(lhs[0][1]):
                            k *= lhs[0][1][d]
                elif op == "convolution" and lhs:
                    k = math.prod(lhs[0][1][1:]) if lhs[0][1] else 1
                f = m * 2.0 * math.prod(res[0][1] or [1]) * k if res else 0.0
                stats.flops += f
                comp_dot_flops += f
                continue
            kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if kind is not None:
                if op.endswith("-done"):
                    continue
                res_b = _nbytes(ins.shapes)
                op_b = sum(_nbytes(tab.get(o, [])) for o in ins.operands)
                b = m * max(res_b, op_b)
                per_kind[kind] += b
                stats.collective_bytes += b
                stats.n_collective_ops += m
                stats.bytes_accessed += m * (res_b + op_b)
                continue
            if in_fused:
                continue
            if op in (
                "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
                "while", "call", "conditional", "after-all", "partition-id",
                "replica-id", "iota", "copy-start", "copy-done",
            ):
                continue
            res_b = _nbytes(ins.shapes)
            op_b = sum(_nbytes(tab.get(o, [])) for o in ins.operands)
            stats.bytes_accessed += m * (res_b + op_b)
        if comp_dot_flops:
            stats.dot_flops_by_comp[comp] = comp_dot_flops

    stats.collectives = dict(per_kind)
    stats.trip_counts = {c: mult[c] for c in mult if mult[c] > 1.5 and c not in fused}
    return stats
