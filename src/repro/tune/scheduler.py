"""Trial-parallel scheduling onto mesh slices.

The HPO analogue of data parallelism: a pod's mesh is sliced into K disjoint
sub-meshes; each concurrently-running trial trains on one slice.  When ASHA
prunes a trial, its slice is freed and immediately backfilled with a fresh
``study.ask()`` — elastic scaling at the trial level with no global barrier
(pruning *is* the straggler mitigation).

On CPU we exercise the same code path with a host mesh (tests); on TPU the
slices come from ``launch.mesh.slice_mesh(make_production_mesh(), K)``.
"""

from __future__ import annotations

import threading
from typing import Callable

import repro.core as hpo
from repro.core import telemetry
from repro.core.frozen import TrialState

__all__ = ["TrialSliceScheduler"]


class TrialSliceScheduler:
    def __init__(
        self,
        study: hpo.Study,
        meshes: list,
        run_trial: Callable,  # (trial, mesh) -> float  (raises TrialPruned)
        backfill_batch: int = 1,
    ):
        """``backfill_batch > 1`` claims replacement trials in waves of that
        size through ``study.ask(n)`` instead of one scalar ask per freed
        slice: each wave is one storage round trip *and* one joint-sampling
        block per parameter group (``BaseSampler.sample_joint``), so a
        multivariate sampler fits its Parzen/posterior once per wave rather
        than once per backfill.  The default of 1 keeps the fully elastic
        per-slice behavior."""
        self.study = study
        self.meshes = meshes
        self.run_trial = run_trial
        self.backfill_batch = max(1, int(backfill_batch))
        self._prefetched: list = []
        self._events: list = []
        self._lock = threading.Lock()

    def _log(self, kind: str, slice_id: int, trial_number: int) -> None:
        with self._lock:
            self._events.append((kind, slice_id, trial_number))
        if telemetry.enabled():  # start/done/pruned/failed per-slice throughput
            telemetry.inc(f"scheduler.{kind}")

    @property
    def events(self) -> list:
        return list(self._events)

    def run(self, n_trials: int) -> None:
        """Run ``n_trials`` total across the slices; each slice loops
        ask -> train -> tell, backfilling as soon as its trial finishes or is
        pruned.

        The opening wave is claimed with one batched ``study.ask(n)`` — one
        storage round trip seeds every slice — after which backfill stays
        elastic (one ask per freed slice, no global barrier)."""
        budget = [n_trials]
        lock = threading.Lock()

        seed_want = min(n_trials, len(self.meshes))
        if seed_want > 0:
            # the seed wave honors generation alignment too: on a warm study
            # a popsize-aware sampler must not draw one oversized block
            seed_want = max(1, min(
                seed_want, self.study.sampler.joint_wave_size(self.study, seed_want)
            ))
        seeded: list = list(self.study.ask(seed_want))

        def take() -> bool:
            with lock:
                if budget[0] <= 0:
                    return False
                budget[0] -= 1
                return True

        def next_trial():
            with lock:
                if seeded:
                    return seeded.pop(0)
                if self._prefetched:
                    return self._prefetched.pop(0)
                if self.backfill_batch > 1:
                    # claim a whole backfill wave in one round trip; peers
                    # freed while this ask is in flight drain the surplus.
                    # Generation-based samplers (CMA-ES, NSGA-II) cap the
                    # wave at their population size so each block aligns
                    # with exactly one generation.
                    want = max(1, min(
                        self.backfill_batch,
                        self.study.sampler.joint_wave_size(self.study, self.backfill_batch),
                    ))
                    self._prefetched.extend(self.study.ask(want))
                    return self._prefetched.pop(0)
            return self.study.ask()

        def slice_worker(slice_id: int, mesh) -> None:
            while take():
                trial = next_trial()
                self._log("start", slice_id, trial.number)
                try:
                    value = self.run_trial(trial, mesh)
                except hpo.TrialPruned:
                    # record the highest-step reported value as the final
                    # value (matching Study._run_one's last_step choice); the
                    # report path already tracked it locally, so no storage
                    # refetch is needed.  A NaN final report is recorded with
                    # no value (Study.tell would reclassify NaN as FAIL).
                    last = trial.last_reported
                    final = last[1] if last is not None and last[1] == last[1] else None
                    self.study.tell(trial, final, state=TrialState.PRUNED)
                    self._log("pruned", slice_id, trial.number)
                    continue
                except Exception:
                    self.study.tell(trial, state=TrialState.FAIL)
                    self._log("failed", slice_id, trial.number)
                    continue
                self.study.tell(trial, value)
                self._log("done", slice_id, trial.number)

        threads = [
            threading.Thread(target=slice_worker, args=(i, m), daemon=True)
            for i, m in enumerate(self.meshes)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # return unevaluated claims (seed leftovers on early stop, surplus
        # from the last backfill wave) to the WAITING queue
        leftovers = seeded + self._prefetched
        self._prefetched = []
        if leftovers:
            self.study._release_unrun(leftovers)
