from __future__ import annotations

from .objective import LMTuneSpec, make_lm_objective
from .scheduler import TrialSliceScheduler

__all__ = ["LMTuneSpec", "make_lm_objective", "TrialSliceScheduler"]
