"""Define-by-run objectives over the model zoo.

This is the paper's Figure 3/4 pattern at framework scale: the *trial object*
dynamically constructs the model architecture (family, depth, width, MoE
topology), the optimizer, and the schedule — then trains the candidate with
``repro.train`` and reports eval losses to the pruner at every eval step.
Pruned trials stop immediately and never checkpoint (ASHA's no-repechage
design, paper §3.2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax

import repro.core as hpo
from repro.models.config import BlockDef, ModelConfig
from repro.train import SyntheticLM, TrainConfig, Trainer

__all__ = ["LMTuneSpec", "make_lm_objective", "suggest_model_config", "suggest_train_config"]


@dataclasses.dataclass(frozen=True)
class LMTuneSpec:
    """Budget/limits for one tuning study (kept CPU-sized by default)."""

    vocab: int = 256
    seq: int = 64
    batch: int = 8
    total_steps: int = 60
    eval_every: int = 10
    max_layers: int = 4
    max_width: int = 128
    families: tuple = ("dense", "mlstm", "mamba2", "moe")


def suggest_model_config(trial, spec: LMTuneSpec) -> ModelConfig:
    """Paper Fig. 3: a heterogeneous space across architecture families, each
    with its own conditional sub-space — expressible as plain Python."""
    family = trial.suggest_categorical("family", list(spec.families))
    n_layers = trial.suggest_int("n_layers", 1, spec.max_layers)
    width_exp = trial.suggest_int("width_exp", 5, int(math.log2(spec.max_width)))
    d_model = 2**width_exp
    common = dict(
        vocab=spec.vocab, d_model=d_model, n_layers=n_layers,
        q_chunk=16, ce_chunk=16, param_dtype="float32",
    )
    if family == "dense":
        n_heads = trial.suggest_categorical("n_heads", [2, 4])
        ff_mult = trial.suggest_int("ff_mult", 1, 4)
        window = trial.suggest_categorical("window", [-1, 16])
        return ModelConfig(
            name=f"tuned-dense-{trial.number}",
            n_heads=n_heads, n_kv_heads=n_heads,
            d_ff=d_model * ff_mult,
            superblock=(BlockDef(kind="attn", window=window),),
            n_superblocks=n_layers,
            **common,
        )
    if family == "mlstm":
        return ModelConfig(
            name=f"tuned-mlstm-{trial.number}",
            n_heads=trial.suggest_categorical("ssm_heads", [2, 4]),
            n_kv_heads=2, d_ff=0,
            superblock=(BlockDef(kind="mlstm", ffn="none"),),
            n_superblocks=n_layers,
            ssm_proj_factor=trial.suggest_int("proj_factor", 1, 2),
            **common,
        )
    if family == "mamba2":
        return ModelConfig(
            name=f"tuned-mamba2-{trial.number}",
            n_heads=4, n_kv_heads=4, d_ff=0,
            superblock=(BlockDef(kind="mamba2", ffn="none"),),
            n_superblocks=n_layers,
            ssm_state=trial.suggest_categorical("ssm_state", [8, 16]),
            ssm_head_dim=16, ssm_chunk=16,
            **common,
        )
    # moe
    n_exp = trial.suggest_categorical("n_experts", [4, 8])
    return ModelConfig(
        name=f"tuned-moe-{trial.number}",
        n_heads=4, n_kv_heads=2,
        d_ff=d_model,
        superblock=(BlockDef(kind="attn", ffn="moe"),),
        n_superblocks=n_layers,
        moe_experts=n_exp,
        moe_top_k=trial.suggest_int("top_k", 1, 2),
        moe_d_ff=d_model,
        moe_group=64,
        **common,
    )


def suggest_train_config(trial, spec: LMTuneSpec) -> TrainConfig:
    """Paper Fig. 4's create_optimizer: the optimizer space is a separate,
    independently-editable method."""
    return TrainConfig(
        lr=trial.suggest_float("lr", 1e-4, 1e-1, log=True),
        warmup_steps=trial.suggest_int("warmup", 0, 20),
        weight_decay=trial.suggest_float("weight_decay", 1e-3, 0.3, log=True),
        total_steps=spec.total_steps,
        eval_every=spec.eval_every,
        checkpoint_every=10**9,
        seed=trial.number,
    )


def make_lm_objective(spec: LMTuneSpec | None = None, workdir: str | None = None) -> Callable:
    spec = spec or LMTuneSpec()

    def objective(trial) -> float:
        cfg = suggest_model_config(trial, spec)
        tcfg = suggest_train_config(trial, spec)
        data = SyntheticLM(cfg, batch=spec.batch, seq=spec.seq, seed=0)

        def report(step: int, loss: float) -> bool:
            trial.report(loss, step)
            return trial.should_prune()

        trainer = Trainer(cfg, tcfg, data, workdir=None, report_fn=report)
        result = trainer.run()
        if result.get("pruned"):
            raise hpo.TrialPruned(f"pruned at step {result['step']}")
        trial.set_user_attr("final_step", result["step"])
        return result["last_loss"]

    return objective
