"""Mamba2 / SSD block (Dao & Gu, 2024) — the zamba2 backbone.

Train/prefill use the chunked SSD algorithm: intra-chunk quadratic attention
-like contraction + inter-chunk linear recurrence over chunk states (a
``lax.scan`` over chunks).  The Pallas ``ssd`` kernel implements the same
chunk schedule with VMEM-resident carry.  Decode is the O(1) recurrent
update on state [B, H, P, N].
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Spec, rms_norm

__all__ = [
    "mamba2_specs",
    "mamba2_block_full",
    "mamba2_block_decode",
    "empty_mamba2_state",
    "ssd_chunked",
]


def mamba2_specs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_ch = di + 2 * G * N
    return {
        "norm": Spec((d,), ("embed",), init="zeros"),
        "w_in": Spec(
            (d, 2 * di + 2 * G * N + H), ("fsdp_embed", "mlp"), std=1.0 / math.sqrt(d)
        ),
        "conv_w": Spec((cfg.ssm_conv, conv_ch), (None, "mlp"), std=0.1),
        "conv_b": Spec((conv_ch,), ("mlp",), init="zeros"),
        "A_log": Spec((H,), ("heads",), init="ones"),  # A = -exp(A_log)
        "D": Spec((H,), ("heads",), init="ones"),
        "dt_bias": Spec((H,), ("heads",), init="zeros"),
        "out_norm": Spec((di,), ("mlp",), init="zeros"),
        "w_out": Spec((di, d), ("mlp", "fsdp_embed"), std=1.0 / math.sqrt(di)),
    }


def _split_in(p, x, cfg):
    """in_proj + causal depthwise conv.  Returns z, xh, B, C, dt."""
    b, S, d = x.shape
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    z = proj[..., :di]
    conv_in = proj[..., di : di + di + 2 * G * N]
    dt = proj[..., di + di + 2 * G * N :]
    return z, conv_in, dt, (di, H, G, N)


def _causal_conv(conv_in, w, bias, state=None):
    """Depthwise causal conv along S.  conv_in [B,S,C]; w [K,C].  If ``state``
    ([B,K-1,C]) is given, it is prepended (decode/prefill continuation) and
    the trailing K-1 inputs are returned as the new state."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((conv_in.shape[0], K - 1, conv_in.shape[2]), conv_in.dtype)
    else:
        pad = state.astype(conv_in.dtype)
    xp = jnp.concatenate([pad, conv_in], axis=1)
    out = sum(
        xp[:, i : i + conv_in.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    new_state = xp[:, -(K - 1) :, :]
    return jax.nn.silu(out + bias[None, None, :]), new_state


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int = 128, initial_state=None):
    """Chunked SSD.  xh [B,S,H,P]; dt [B,S,H] (post-softplus); A [H] (<0);
    Bm, Cm [B,S,G,N] (G divides H).  Returns (y [B,S,H,P], final_state
    [B,H,P,N])."""
    b, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    L = min(chunk, S)
    while S % L != 0:
        L //= 2
    n = S // L

    dA = dt * A[None, None, :]  # [B,S,H] log-decay per step (negative)
    xdt = xh * dt[..., None]

    def resh(t, feat_shape):
        return t.reshape(b, n, L, *feat_shape)

    dA_c = resh(dA, (H,))
    x_c = resh(xdt, (H, P))
    B_c = jnp.repeat(resh(Bm, (G, N)), rep, axis=3)  # [b,n,L,H,N]
    C_c = jnp.repeat(resh(Cm, (G, N)), rep, axis=3)

    cum = jnp.cumsum(dA_c, axis=2)  # [b,n,L,H] inclusive
    total = cum[:, :, -1:, :]  # [b,n,1,H]

    # intra-chunk (diagonal) term: decay[t,s] = exp(cum_t - cum_s) for s<=t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,n,L,L,H]
    causal = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    cb = jnp.einsum("bcthn,bcshn->bctsh", C_c, B_c, preferred_element_type=jnp.float32)
    y_diag = jnp.einsum(
        "bctsh,bctsh,bcshp->bcthp", cb, decay.astype(jnp.float32), x_c.astype(jnp.float32)
    )

    # chunk states: sum_s exp(total - cum_s) B_s x_s -> [b,n,H,N,P]
    decay_out = jnp.exp(total - cum)  # [b,n,L,H]
    states = jnp.einsum(
        "bclhn,bclh,bclhp->bchnp",
        B_c.astype(jnp.float32), decay_out.astype(jnp.float32), x_c.astype(jnp.float32),
    )

    # inter-chunk recurrence over n chunks
    chunk_decay = jnp.exp(total[:, :, 0, :])  # [b,n,H]
    init = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, H, P, N), jnp.float32)
    )
    # note states above are [b,n,H,N,P]; transpose to [b,n,H,P,N]
    states = states.transpose(0, 1, 2, 4, 3)

    def scan_body(carry, args):
        st, dec = args  # st [b,H,P,N], dec [b,H]
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *entering* this chunk

    final, entering = jax.lax.scan(
        scan_body,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)  # [b,n,H,P,N]

    # inter-chunk (off-diagonal) contribution: C_t . state_in * exp(cum_t)
    y_off = jnp.einsum(
        "bclhn,bchpn,bclh->bclhp",
        C_c.astype(jnp.float32), entering, jnp.exp(cum).astype(jnp.float32),
    )
    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, final


def _mamba_out(p, y, z, xh, cfg, dtype):
    b, S, H, P = y.shape
    di = H * P
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    yf = rms_norm(y.reshape(b, S, di).astype(dtype), p["out_norm"], cfg.norm_eps)
    gated = yf * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", gated, p["w_out"].astype(dtype))


def mamba2_block_full(p, x, cfg, bdef, positions, cache=None, cache_index=None):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    z, conv_in, dt_raw, (di, H, G, N) = _split_in(p, xn, cfg)
    conv_state = cache["conv"] if cache is not None else None
    conved, new_conv = _causal_conv(conv_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype), conv_state)
    b, S, _ = x.shape
    P = cfg.ssm_head_dim
    xh = conved[..., :di].reshape(b, S, H, P)
    Bm = conved[..., di : di + G * N].reshape(b, S, G, N)
    Cm = conved[..., di + G * N :].reshape(b, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    init_state = cache["state"] if cache is not None else None
    y, final = ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk, initial_state=init_state)
    out = _mamba_out(p, y, z, xh, cfg, x.dtype)
    new_cache = {"conv": new_conv, "state": final} if cache is not None else None
    return out, new_cache


def mamba2_block_decode(p, x, cfg, bdef, cache, index):
    """x: [B,1,d]; O(1) state update."""
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    z, conv_in, dt_raw, (di, H, G, N) = _split_in(p, xn, cfg)
    conved, new_conv = _causal_conv(
        conv_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype), cache["conv"]
    )
    b = x.shape[0]
    P = cfg.ssm_head_dim
    xh = conved[..., :di].reshape(b, 1, H, P)
    Bm = conved[..., di : di + G * N].reshape(b, 1, G, N)
    Cm = conved[..., di + G * N :].reshape(b, 1, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    rep = H // G
    Bh = jnp.repeat(Bm[:, 0], rep, axis=1).astype(jnp.float32)  # [b,H,N]
    Ch = jnp.repeat(Cm[:, 0], rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])  # [b,H]
    x0 = xh[:, 0].astype(jnp.float32) * dt[..., None]  # [b,H,P]
    state = cache["state"] * dA[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", x0, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)[:, None]  # [b,1,H,P]
    out = _mamba_out(p, y, z, xh, cfg, x.dtype)
    return out, {"conv": new_conv, "state": state}


def empty_mamba2_state(cfg, batch: int) -> dict:
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    conv_ch = di + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
        "state": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }
