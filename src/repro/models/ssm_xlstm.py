"""xLSTM blocks (Beck et al., 2024): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, truly recurrent).

mLSTM is evaluated in its *stabilized parallel form* for train/prefill —
the same q-chunked lazy pattern as attention but with an exponential-gating
decay matrix instead of softmax — and in its recurrent form (O(1) state
``C``: [B,H,D,D]) for decode.  This is what makes xlstm-1.3b the designated
``long_500k`` architecture: decode cost is independent of context length.

sLSTM has a genuine sequential dependency (recurrent weights feed h_{t-1}
into the gates), so it is evaluated with ``lax.scan`` over time in all modes —
the paper's own framing; we keep the 7:1 mLSTM:sLSTM pattern so the scans are
rare.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Spec, rms_norm

__all__ = [
    "mlstm_specs",
    "slstm_specs",
    "mlstm_block_full",
    "mlstm_block_decode",
    "slstm_block_full",
    "slstm_block_decode",
    "empty_mlstm_state",
    "empty_slstm_state",
]


# -- specs ----------------------------------------------------------------------------


def mlstm_specs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_proj_factor * d  # inner width
    H = cfg.n_heads
    D = di // H
    return {
        "norm": Spec((d,), ("embed",), init="zeros"),
        "w_up": Spec((d, 2 * di), ("fsdp_embed", "mlp"), std=1.0 / math.sqrt(d)),
        # block-diagonal per-head q/k (v = conv output directly)
        "wq": Spec((H, D, D), ("heads", "head_dim", None), std=1.0 / math.sqrt(D)),
        "wk": Spec((H, D, D), ("heads", "head_dim", None), std=1.0 / math.sqrt(D)),
        "w_if": Spec((di, 2 * H), ("mlp", "heads"), std=1.0 / math.sqrt(di)),
        "b_f": Spec((H,), ("heads",), init="ones"),  # forget-gate bias > 0 at init
        "out_norm": Spec((di,), ("mlp",), init="zeros"),
        "w_down": Spec((di, d), ("mlp", "fsdp_embed"), std=1.0 / math.sqrt(di)),
    }


def slstm_specs(cfg) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    D = d // H
    return {
        "norm": Spec((d,), ("embed",), init="zeros"),
        "w_zifo": Spec((d, 4 * d), ("fsdp_embed", "mlp"), std=1.0 / math.sqrt(d)),
        # block-diagonal recurrent weights per head
        "r_zifo": Spec((4, H, D, D), (None, "heads", "head_dim", None), std=1.0 / math.sqrt(D)),
        "b_zifo": Spec((4 * d,), ("mlp",), init="zeros"),
        "out_norm": Spec((d,), ("embed",), init="zeros"),
        "w_out": Spec((d, d), ("fsdp_embed", "embed"), std=1.0 / math.sqrt(d)),
    }


# -- mLSTM ---------------------------------------------------------------------------------


def _mlstm_qkvif(p, x, cfg):
    """Project to per-head q, k, v, and i/f gate logits.  x: [B,S,d]."""
    B, S, d = x.shape
    di = cfg.ssm_proj_factor * d
    H = cfg.n_heads
    D = di // H
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    xc, z = up[..., :di], up[..., di:]
    xh = xc.reshape(B, S, H, D)
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"].astype(x.dtype))
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"].astype(x.dtype)) / math.sqrt(D)
    v = xh
    gates = jnp.einsum("bse,eh->bsh", xc, p["w_if"].astype(x.dtype)).astype(jnp.float32)
    logi = gates[..., : H]
    logf = jax.nn.log_sigmoid(gates[..., H:] + p["b_f"].astype(jnp.float32))
    return q, k, v, z, logi, logf


def mlstm_parallel(q, k, v, logi, logf, q_chunk: int = 256):
    """Stabilized parallel mLSTM.  q,k,v: [B,S,H,D]; logi/logf: [B,S,H] (f32).

    h_t = sum_s D_ts (q_t.k_s) v_s / max(|sum_s D_ts (q_t.k_s)|, exp(-m_t)),
    log D_ts = F_t - F_s + logi_s (s<=t),  m_t = max_s log D_ts.
    """
    B, S, H, D = q.shape
    F = jnp.cumsum(logf, axis=1)  # [B,S,H] inclusive
    qc = min(q_chunk, S)
    while S % qc != 0:
        qc //= 2
    n = S // qc

    qs = q.reshape(B, n, qc, H, D).transpose(1, 0, 2, 3, 4)
    Fq = F.reshape(B, n, qc, H).transpose(1, 0, 2, 3)
    # NOTE: k is already scaled by 1/sqrt(D) at projection time (recurrent and
    # parallel paths must agree), so no extra score scaling here.
    scale = 1.0

    @jax.checkpoint
    def body(_, args):
        i, qb, Fb = args  # qb [B,qc,H,D], Fb [B,qc,H]
        q_pos = i * qc + jnp.arange(qc)
        k_pos = jnp.arange(S)
        # logD: [B, H, qc, S]
        logD = (
            Fb.transpose(0, 2, 1)[:, :, :, None]
            - F.transpose(0, 2, 1)[:, :, None, :]
            + logi.transpose(0, 2, 1)[:, :, None, :]
        )
        causal = k_pos[None, :] <= q_pos[:, None]
        logD = jnp.where(causal[None, None], logD, -jnp.inf)
        m = jnp.max(logD, axis=-1, keepdims=True)  # [B,H,qc,1]
        m = jnp.maximum(m, -1e30)
        Dmat = jnp.exp(logD - m)
        qk = jnp.einsum("bqhd,bshd->bhqs", qb, k, preferred_element_type=jnp.float32) * scale
        w = qk * Dmat
        numer = jnp.einsum("bhqs,bshd->bqhd", w.astype(q.dtype), v)
        denom = jnp.sum(w, axis=-1)  # [B,H,qc]
        denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m[..., 0]))
        h = numer / denom.transpose(0, 2, 1)[..., None].astype(q.dtype)
        return None, h

    _, hs = jax.lax.scan(body, None, (jnp.arange(n), qs, Fq))
    return hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


def mlstm_recurrent_step(state, q, k, v, logi, logf):
    """One decode step.  state: dict(C [B,H,D,D], n [B,H,D], m [B,H]);
    q,k,v: [B,1,H,D]; logi/logf: [B,1,H]."""
    C, nvec, m = state["C"], state["n"], state["m"]
    logi = logi[:, 0].astype(jnp.float32)
    logf = logf[:, 0].astype(jnp.float32)
    q_, k_, v_ = q[:, 0], k[:, 0], v[:, 0]

    m_new = jnp.maximum(logf + m, logi)
    f_ = jnp.exp(logf + m - m_new)[..., None]
    i_ = jnp.exp(logi - m_new)[..., None]
    C_new = f_[..., None] * C + i_[..., None] * jnp.einsum("bhd,bhe->bhde", k_, v_)
    n_new = f_ * nvec + i_ * k_
    numer = jnp.einsum("bhd,bhde->bhe", q_, C_new)
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q_, n_new)), jnp.exp(-m_new)
    )[..., None]
    h = (numer / denom)[:, None].astype(q.dtype)
    return {"C": C_new, "n": n_new, "m": m_new}, h


def _mlstm_out(p, h, z, cfg, x_dtype):
    B, S, H, D = h.shape
    hf = rms_norm(h.reshape(B, S, H * D), p["out_norm"], cfg.norm_eps)
    gated = hf * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", gated, p["w_down"].astype(x_dtype))


def mlstm_block_full(p, x, cfg, bdef, positions, cache=None, cache_index=None):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v, z, logi, logf = _mlstm_qkvif(p, xn, cfg)
    h = mlstm_parallel(q, k, v, logi, logf, q_chunk=cfg.q_chunk)
    out = _mlstm_out(p, h, z, cfg, x.dtype)
    new_cache = None
    if cache is not None:
        # fold the processed prefix into the recurrent state for decode:
        # replay recurrences in one scan over time (state-space prefill)
        def step(st, args):
            st, _ = mlstm_recurrent_step(st, *[a[:, None] for a in args])
            return st, None

        new_cache, _ = jax.lax.scan(
            step,
            cache,
            (
                q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3), v.transpose(1, 0, 2, 3),
                logi.transpose(1, 0, 2), logf.transpose(1, 0, 2),
            ),
        )
    return out, new_cache


def mlstm_block_decode(p, x, cfg, bdef, cache, index):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v, z, logi, logf = _mlstm_qkvif(p, xn, cfg)
    new_state, h = mlstm_recurrent_step(cache, q, k, v, logi, logf)
    out = _mlstm_out(p, h, z, cfg, x.dtype)
    return out, new_state


def empty_mlstm_state(cfg, batch: int) -> dict:
    di = cfg.ssm_proj_factor * cfg.d_model
    H = cfg.n_heads
    D = di // H
    return {
        "C": jnp.zeros((batch, H, D, D), jnp.float32),
        "n": jnp.zeros((batch, H, D), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


# -- sLSTM --------------------------------------------------------------------------------


def _slstm_scan(p, zifo, cfg, state):
    """Sequential sLSTM over time.  zifo: [B,S,4d] pre-activations (input part);
    recurrent part added step-by-step.  Returns (h_seq [B,S,d], final state)."""
    B, S, d4 = zifo.shape
    d = d4 // 4
    H = cfg.n_heads
    D = d // H
    R = p["r_zifo"].astype(jnp.float32)  # [4,H,D,D]

    @jax.checkpoint  # BPTT residual = the 4 state tensors only; gates recomputed
    def step(st, u_t):  # u_t: [B, 4d]
        c, n, h, m = st["c"], st["n"], st["h"], st["m"]  # [B,H,D] each, m [B,H,D]
        hr = h  # [B,H,D]
        rec = jnp.einsum("bhd,ghde->gbhe", hr, R)  # [4,B,H,D]
        u = u_t.reshape(B, 4, H, D).transpose(1, 0, 2, 3).astype(jnp.float32) + rec
        z_t = jnp.tanh(u[0])
        i_t = u[1]
        f_t = u[2]
        o_t = jax.nn.sigmoid(u[3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_ = jnp.exp(i_t - m_new)
        f_ = jnp.exp(f_t + m - m_new)
        c_new = f_ * c + i_ * z_t
        n_new = jnp.maximum(f_ * n + i_, jnp.exp(-m_new))
        h_new = o_t * c_new / n_new
        return (
            {"c": c_new, "n": n_new, "h": h_new, "m": m_new},
            h_new.reshape(B, d),
        )

    final, hs = jax.lax.scan(step, state, zifo.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), final


def slstm_block_full(p, x, cfg, bdef, positions, cache=None, cache_index=None):
    B, S, d = x.shape
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    zifo = jnp.einsum("bsd,de->bse", xn, p["w_zifo"].astype(x.dtype)) + p["b_zifo"].astype(x.dtype)
    state = cache if cache is not None else empty_slstm_state(cfg, B)
    hs, final = _slstm_scan(p, zifo, cfg, state)
    hn = rms_norm(hs.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", hn, p["w_out"].astype(x.dtype))
    return out, (final if cache is not None else None)


def slstm_block_decode(p, x, cfg, bdef, cache, index):
    out, final = slstm_block_full(p, x, cfg, bdef, None, cache=cache, cache_index=index)
    return out, final


def empty_slstm_state(cfg, batch: int) -> dict:
    H = cfg.n_heads
    D = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, D), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, H, D), -1e30, jnp.float32)}
