"""Logical-axis sharding: every parameter/activation declares *logical* axes;
a rules table maps them to mesh axes (GSPMD).  Divisibility is checked at
apply time — a logical axis whose size does not divide the assigned mesh axes
falls back to replication (e.g. kv_heads=4 on a 16-way "model" axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "ShardingRules",
    "TRAIN_RULES",
    "SERVE_RULES",
    "logical_to_spec",
    "logical_to_sharding",
    "tree_shardings",
    "with_logical_constraint",
]

Axes = "str | tuple[str, ...] | None"


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    rules: dict

    def mesh_axes(self, logical: str | None) -> "tuple[str, ...]":
        if logical is None:
            return ()
        ax = self.rules.get(logical)
        if ax is None:
            return ()
        return (ax,) if isinstance(ax, str) else tuple(ax)


# Production rules. "pod" and "data" are both batch axes; "model" is the
# tensor/expert axis.  fsdp: weight 'embed' dims are additionally sharded over
# the batch axes for ZeRO-3-style memory scaling (GSPMD inserts the
# all-gathers).  Rules intentionally over-specify: missing mesh axes (e.g. no
# "pod" on the single-pod mesh) are filtered out at spec build time.
TRAIN_RULES = ShardingRules(
    rules={
        "batch": ("pod", "data"),
        # Megatron-style sequence parallelism: between layers, activations are
        # sharded over the model axis along seq; GSPMD all-gathers k/v inside
        # attention.  This divides the scan-over-layers residual stack (the
        # dominant train-memory term) by the TP degree.
        "seq": "model",
        "embed": None,
        "fsdp_embed": ("pod", "data"),  # weights' d_model dim under FSDP
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_group": None,
        "kv_lora": None,
        "conv": None,
        "state": None,
        "layers": None,
        "stage": "stage",  # only present on pipeline meshes
        "kv_seq": None,
    }
)

# Serving: no gradient/optimizer memory pressure -> keep weights replicated
# over the batch axes (fsdp off) to avoid per-step all-gathers; batch still
# over ("pod","data"); long-context decode shards the KV cache sequence dim
# over the batch axes (batch==1 cells).
SERVE_RULES = ShardingRules(
    rules={
        **TRAIN_RULES.rules,
        "seq": None,  # no residual stack to shard; keep activations whole
        "fsdp_embed": None,
        "kv_seq": ("pod", "data"),
        # caches whose head count does not divide the model axis (musicgen 24H,
        # gemma2 kv=8, tinyllama kv=4) shard the head_dim / MLA latent instead —
        # attention contracts these dims, GSPMD inserts the partial-sum
        # all-reduce (cheap at decode batch sizes).
        "head_dim": "model",
        "kv_lora": "model",
    }
)


def logical_to_spec(
    logical: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules,
) -> PartitionSpec:
    """Build a PartitionSpec, dropping mesh axes that are absent, already
    used, or do not divide the dimension."""
    used: set[str] = set()
    spec: list[Any] = []
    for dim, name in zip(shape, logical):
        axes = []
        for ax in rules.mesh_axes(name):
            if ax not in mesh.shape or ax in used:
                continue
            group = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
            if dim % (group * mesh.shape[ax]) != 0:
                continue
            axes.append(ax)
            used.add(ax)
        if not axes:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
        else:
            spec.append(tuple(axes))
    # trim trailing Nones
    while spec and spec[-1] is None:
        spec.pop()
    return PartitionSpec(*spec)


def logical_to_sharding(
    logical: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical, shape, mesh, rules))


def tree_shardings(shape_tree, logical_tree, mesh: Mesh, rules: ShardingRules):
    """Map a pytree of ShapeDtypeStructs + parallel tree of logical axes to
    NamedShardings."""
    return jax.tree.map(
        lambda s, log: logical_to_sharding(log, s.shape, mesh, rules),
        shape_tree,
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def with_logical_constraint(x, logical: Sequence[str | None], mesh: Mesh | None, rules: ShardingRules):
    """Activation sharding hint (no-op when no mesh is active)."""
    if mesh is None or mesh.empty:
        return x
    sharding = logical_to_sharding(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, sharding)


# -- ambient activation-sharding context ------------------------------------------
#
# Model code is pure and mesh-agnostic; launchers activate a (mesh, rules)
# context at trace time and the layers call ``constrain`` to anchor activation
# shardings (batch over ("pod","data"), experts over "model", ...).  Without
# these anchors GSPMD can propagate a *replicated* batch through the layer
# scan — catastrophic for memory (verified on the smollm dry-run: 409 GiB/dev
# before anchors, ~1 GiB after).

_ACTIVE: list = []


class activation_sharding:
    def __init__(self, mesh: Mesh, rules: ShardingRules):
        self.pair = (mesh, rules)

    def __enter__(self):
        _ACTIVE.append(self.pair)
        return self

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def constrain(x, logical: Sequence[str | None]):
    """Sharding anchor using the ambient (mesh, rules); identity when absent."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    return with_logical_constraint(x, logical, mesh, rules)


def wrap_with_sharding_ctx(fn, mesh: Mesh, rules: ShardingRules):
    """Make ``fn`` trace (and thus jit-compile) inside the activation-sharding
    context."""

    def wrapped(*args, **kwargs):
        with activation_sharding(mesh, rules):
            return fn(*args, **kwargs)

    return wrapped
