"""Attention: GQA with sliding-window / logit-softcap, MLA (DeepSeek), and
KV-cache decode paths.

The full-sequence path is *q-chunked*: we scan over query blocks so the
[B, H, S, T] score tensor never materializes beyond one block — the pure-JAX
analogue of the Pallas `flash_attention` kernel (and numerically identical to
`kernels.ref.attention_ref`).  On TPU the Pallas kernel replaces the inner
block computation; the chunk structure is what makes 32k-token prefill fit
HBM on the dry-run meshes.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Spec, apply_rope, rope, softcap
from .sharding import constrain

__all__ = [
    "attn_specs",
    "mla_specs",
    "attention_full",
    "attention_decode",
    "attn_block_full",
    "attn_block_decode",
    "mla_block_full",
    "mla_block_decode",
    "empty_kv_cache",
    "empty_mla_cache",
]


# -- parameter specs -----------------------------------------------------------------


def attn_specs(cfg) -> dict:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    std = 1.0 / math.sqrt(d)
    return {
        "wq": Spec((d, H, Dh), ("fsdp_embed", "heads", "head_dim"), std=std),
        "wk": Spec((d, KV, Dh), ("fsdp_embed", "kv_heads", "head_dim"), std=std),
        "wv": Spec((d, KV, Dh), ("fsdp_embed", "kv_heads", "head_dim"), std=std),
        "wo": Spec((H, Dh, d), ("heads", "head_dim", "fsdp_embed"), std=1.0 / math.sqrt(H * Dh)),
    }


def mla_specs(cfg) -> dict:
    """Multi-head Latent Attention (DeepSeek-V2).  K/V are stored compressed:
    c_kv = x @ w_dkv (kv_lora dims) plus a single shared rope key head."""
    d, H = cfg.d_model, cfg.n_heads
    L = cfg.kv_lora_rank
    nope, rp, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    std = 1.0 / math.sqrt(d)
    return {
        "wq": Spec((d, H, nope + rp), ("fsdp_embed", "heads", "head_dim"), std=std),
        "w_dkv": Spec((d, L), ("fsdp_embed", "kv_lora"), std=std),
        "kv_norm": Spec((L,), ("kv_lora",), init="zeros"),
        "w_kr": Spec((d, rp), ("fsdp_embed", "head_dim"), std=std),
        "w_uk": Spec((L, H, nope), ("kv_lora", "heads", "head_dim"), std=1.0 / math.sqrt(L)),
        "w_uv": Spec((L, H, dv), ("kv_lora", "heads", "head_dim"), std=1.0 / math.sqrt(L)),
        "wo": Spec((H, dv, d), ("heads", "head_dim", "fsdp_embed"), std=1.0 / math.sqrt(H * dv)),
    }


# -- core attention ---------------------------------------------------------------------


def _scores_to_out(scores_f32, v, softcap_val, mask):
    if softcap_val:
        scores_f32 = softcap(scores_f32, softcap_val)
    scores_f32 = jnp.where(mask, scores_f32, -1e30)
    probs = jax.nn.softmax(scores_f32, axis=-1)
    return probs


def attention_full(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, T, KV, D]
    v: jax.Array,  # [B, T, KV, D]
    *,
    window: int = -1,
    attn_softcap: float | None = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    """Causal (optionally sliding-window) attention, scanned over q blocks."""
    B, S, H, D = q.shape
    _, T, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qc = min(q_chunk, S)
    while S % qc != 0:
        qc //= 2
    n = S // qc
    dtype = q.dtype

    qs = q.reshape(B, n, qc, H, D).transpose(1, 0, 2, 3, 4)  # [n, B, qc, H, D]
    k_pos = jnp.arange(T)

    # remat per q-chunk: backward recomputes this chunk's scores instead of
    # saving [n_chunks, B, H, qc, T] residuals (the full S^2 matrix)
    @jax.checkpoint
    def body(_, args):
        i, qb = args  # qb: [B, qc, H, D]
        q_pos = q_offset + i * qc + jnp.arange(qc)
        qg = qb.reshape(B, qc, KV, G, D)
        s = jnp.einsum(
            "bqkgd,btkd->bkgqt", qg, k, preferred_element_type=jnp.float32
        ) * scale  # [B, KV, G, qc, T]
        mask = k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        if kv_len is not None:
            mask &= k_pos[None, :] < kv_len
        probs = _scores_to_out(s, v, attn_softcap, mask[None, None, None])
        o = jnp.einsum("bkgqt,btkd->bqkgd", probs.astype(dtype), v)
        return None, o.reshape(B, qc, H, D)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


def attention_decode(
    q: jax.Array,  # [B, 1, H, D]
    k_cache: jax.Array,  # [B, T, KV, D]
    v_cache: jax.Array,
    index: jax.Array,  # current position (tokens < index are valid)
    *,
    window: int = -1,
    attn_softcap: float | None = None,
) -> jax.Array:
    B, _, H, D = q.shape
    _, T, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum(
        "bkgd,btkd->bkgt", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    k_pos = jnp.arange(T)
    mask = k_pos <= index
    if window > 0:
        mask &= (index - k_pos) < window
    if attn_softcap:
        s = softcap(s, attn_softcap)
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", probs.astype(q.dtype), v_cache)
    return o.reshape(B, 1, H, D)


# -- block-level wrappers (projections + rope + attention) ------------------------------------


def _project_qkv(p, x, cfg, positions, compute_dtype):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute_dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(compute_dtype))
    sin, cos = rope(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


def _is_ring(bdef, cache) -> bool:
    """Sliding-window layers keep only a window-sized ring cache (gemma2's
    local layers: 4096 slots instead of the full context)."""
    return bdef.window > 0 and cache["k"].shape[1] <= bdef.window


def attn_block_full(p, x, cfg, bdef, positions, cache=None, cache_index=None):
    """Full-sequence attention sub-block.  Returns (out, new_cache)."""
    B, S, d = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, x.dtype)
    new_cache = None
    if cache is not None and _is_ring(bdef, cache):
        # prefill a window ring cache: attend locally, store the last W tokens
        # at slots (pos % W).  (Ring prefill assumes cache_index == 0.)
        o = attention_full(
            q, k, v, window=bdef.window, attn_softcap=cfg.attn_softcap,
            q_offset=0, q_chunk=cfg.q_chunk,
        )
        W = cache["k"].shape[1]
        take = min(W, S)
        pos = np.arange(S - take, S)
        slots = np.mod(pos, W)
        kc = cache["k"].at[:, slots].set(k[:, S - take :].astype(cache["k"].dtype))
        vc = cache["v"].at[:, slots].set(v[:, S - take :].astype(cache["v"].dtype))
        new_cache = {"k": kc, "v": vc}
    else:
        # NOTE (§Perf iteration 2, refuted): forcing a Megatron-SP k/v gather
        # here (constrain k/v replicated over "model") made GSPMD replicate the
        # whole attention computation (compute x3.4, memory x4.5 on gemma2).
        # GSPMD's split-KV schedule — seq-sharded k/v with f32 partial-output
        # all-reduces — is the better schedule for this chunk-scan structure.
        if cfg.attn_head_shard and cache is None:
            # Megatron attention: q sharded by heads over "model"; k/v small
            # (few kv heads) and replicated (§Perf iteration 3)
            q = constrain(q, ("batch", None, "heads", "head_dim"))
            k = constrain(k, ("batch", None, "kv_heads", "head_dim"))
            v = constrain(v, ("batch", None, "kv_heads", "head_dim"))
        if cache is not None:
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
            new_cache = {"k": kc, "v": vc}
            k, v = kc, vc
            kv_len = cache_index + S
        else:
            kv_len = None
        o = attention_full(
            q, k, v,
            window=bdef.window,
            attn_softcap=cfg.attn_softcap,
            q_offset=cache_index if cache is not None else 0,
            q_chunk=cfg.q_chunk if cache is None else cfg.prefill_q_chunk,
            kv_len=kv_len,
        )
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache


def attn_block_decode(p, x, cfg, bdef, cache, index):
    """One-token decode with cache update.  x: [B, 1, d]."""
    positions = jnp.full((x.shape[0], 1), index, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, x.dtype)
    if _is_ring(bdef, cache):
        W = cache["k"].shape[1]
        slot = index % W
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        # ring slots hold exactly the last W positions (rope was applied at the
        # absolute position before caching); a slot s is filled iff s <= index.
        o = attention_decode(q, kc, vc, index, window=-1, attn_softcap=cfg.attn_softcap)
    else:
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, index, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, index, 0, 0))
        o = attention_decode(
            q, kc, vc, index, window=bdef.window, attn_softcap=cfg.attn_softcap
        )
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"k": kc, "v": vc}


def empty_kv_cache(cfg, batch: int, capacity: int, dtype, window: int = -1) -> dict:
    if window > 0:
        capacity = min(capacity, window)
    return {
        "k": jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


# -- MLA -------------------------------------------------------------------------------------


def _mla_qkv(p, x, cfg, positions, compute_dtype):
    from .layers import rms_norm

    H = cfg.n_heads
    nope, rp = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute_dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    sin, cos = rope(positions, rp, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)

    c_kv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"].astype(compute_dtype))
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(compute_dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]  # single shared head
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, q_nope, q_rope, c_kv, k_rope, cfg, q_offset, kv_len, compute_dtype, q_chunk):
    """Attention in compressed space.

    Absorb w_uk into q (the MLA trick): score = (q_nope @ w_uk) . c_kv
    + q_rope . k_rope, so the cache stays [T, kv_lora + rope] — this is the
    memory win over GQA.  Values are un-compressed per-head after the probs.
    """
    B, S, H, _ = q_nope.shape
    T = c_kv.shape[1]
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    # q_abs: [B,S,H,L]
    q_abs = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"].astype(compute_dtype))

    qc = min(q_chunk, S)
    while S % qc != 0:
        qc //= 2
    n = S // qc
    k_pos = jnp.arange(T)
    dtype = q_nope.dtype

    qa = q_abs.reshape(B, n, qc, H, -1).transpose(1, 0, 2, 3, 4)
    qr = q_rope.reshape(B, n, qc, H, -1).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def body(_, args):
        i, qab, qrb = args
        q_pos = q_offset + i * qc + jnp.arange(qc)
        s = jnp.einsum("bqhl,btl->bhqt", qab, c_kv, preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhk,btk->bhqt", qrb, k_rope, preferred_element_type=jnp.float32)
        s *= scale
        mask = k_pos[None, :] <= q_pos[:, None]
        if kv_len is not None:
            mask &= k_pos[None, :] < kv_len
        s = jnp.where(mask[None, None], s, -1e30)
        probs = jax.nn.softmax(s, axis=-1)
        # value up-projection after prob-weighting in compressed space:
        # o = (probs @ c_kv) @ w_uv   [B,qc,H,dv]
        ctx = jnp.einsum("bhqt,btl->bqhl", probs.astype(dtype), c_kv)
        o = jnp.einsum("bqhl,lhv->bqhv", ctx, p["w_uv"].astype(compute_dtype))
        return None, o

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qa, qr))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, cfg.v_head_dim)


def mla_block_full(p, x, cfg, bdef, positions, cache=None, cache_index=None):
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions, x.dtype)
    new_cache = None
    kv_len = None
    if cache is not None:
        ckv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_index, 0))
        kr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, cache_index, 0))
        new_cache = {"c_kv": ckv, "k_rope": kr}
        c_kv, k_rope = ckv, kr
        kv_len = cache_index + S
    o = _mla_attend(
        p, q_nope, q_rope, c_kv, k_rope, cfg,
        q_offset=cache_index if cache is not None else 0,
        kv_len=kv_len, compute_dtype=x.dtype,
        q_chunk=cfg.q_chunk if cache is None else cfg.prefill_q_chunk,
    )
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache


def mla_block_decode(p, x, cfg, bdef, cache, index):
    B = x.shape[0]
    positions = jnp.full((B, 1), index, dtype=jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions, x.dtype)
    ckv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, index, 0))
    kr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, index, 0))
    o = _mla_attend(
        p, q_nope, q_rope, ckv, kr, cfg,
        q_offset=index, kv_len=index + 1, compute_dtype=x.dtype, q_chunk=1,
    )
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"c_kv": ckv, "k_rope": kr}


def empty_mla_cache(cfg, batch: int, capacity: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, cfg.qk_rope_dim), dtype),
    }
