"""JAX model zoo: a single scanned-decoder assembly covering dense GQA,
local/global attention, MLA, MoE, xLSTM, Mamba2-hybrid, VLM and audio
backbones (the 10 assigned architectures)."""

from __future__ import annotations

from .config import SHAPES, BlockDef, ModelConfig, ShapeConfig
from .transformer import (
    abstract_params,
    cache_logical,
    count_active_params,
    count_params,
    forward,
    init_cache,
    init_model_params,
    logits_from_hidden,
    loss_fn,
    param_specs,
    params_logical,
)

__all__ = [
    "BlockDef",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "param_specs",
    "init_model_params",
    "abstract_params",
    "params_logical",
    "forward",
    "loss_fn",
    "logits_from_hidden",
    "init_cache",
    "cache_logical",
    "count_params",
    "count_active_params",
]
