"""Mixture-of-Experts FFN with token-choice top-k routing.

Two dispatch modes (selected by ``cfg.moe_dispatch``):

* ``"einsum"`` — the Mesh-TF/GLaM one-hot capacity dispatch under pure pjit.
  Tokens are reshaped into groups of ``moe_group`` so the dispatch tensor is
  [G, S_g, E, C] with C = ceil(S_g*k/E * capacity_factor); GSPMD turns the
  expert-sharded einsums into all-to-all-style collectives.  Robust baseline.
* ``"sort"`` — sort-based dispatch: tokens are argsorted by expert id and
  gathered into [E, C_tot, d] buffers with index arithmetic only (no [T,E,C]
  one-hot materialization).  This is the beyond-paper §Perf optimization —
  it removes the dominant dispatch bytes from the memory roofline term.

Both drop overflow tokens deterministically (capacity policy; combine weights
renormalized over surviving assignments) and add the auxiliary load-balance
loss of Shazeer et al. / Switch.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Spec
from .sharding import constrain

__all__ = ["moe_specs", "moe_ffn", "shared_expert_specs"]


def moe_specs(cfg) -> dict:
    d, E, F = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    std = 1.0 / math.sqrt(d)
    specs = {
        "router": Spec((d, E), ("embed", "experts"), std=std),
        "w1": Spec((E, d, F), ("experts", "fsdp_embed", "mlp"), std=std),
        "w3": Spec((E, d, F), ("experts", "fsdp_embed", "mlp"), std=std),
        "w2": Spec((E, F, d), ("experts", "mlp", "fsdp_embed"), std=1.0 / math.sqrt(F)),
    }
    if cfg.moe_shared_d_ff:
        specs.update(shared_expert_specs(cfg))
    return specs


def shared_expert_specs(cfg) -> dict:
    d, F = cfg.d_model, cfg.moe_shared_d_ff
    std = 1.0 / math.sqrt(d)
    return {
        "sw1": Spec((d, F), ("fsdp_embed", "mlp"), std=std),
        "sw3": Spec((d, F), ("fsdp_embed", "mlp"), std=std),
        "sw2": Spec((F, d), ("mlp", "fsdp_embed"), std=1.0 / math.sqrt(F)),
    }


def _router(p, x, cfg):
    """Returns (topk weights [T,k], topk expert ids [T,k], aux loss)."""
    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    if cfg.moe_norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e (fraction tokens to e) * (mean prob for e)
    E = cfg.moe_experts
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    load = onehot.mean(0)
    importance = probs.mean(0)
    aux = E * jnp.sum(load * importance)
    return w, idx, aux


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(
        math.ceil(tokens_per_group * cfg.moe_top_k / cfg.moe_experts * cfg.moe_capacity)
    )
    return max(c, cfg.moe_top_k)


# -- einsum (one-hot) dispatch --------------------------------------------------------------


def _moe_einsum(p, xt, w, idx, cfg):
    """xt: [T, d] flat tokens."""
    T, d = xt.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    Sg = min(cfg.moe_group, T)
    while T % Sg != 0:
        Sg //= 2
    G = T // Sg
    C = _capacity(Sg, cfg)

    xg = xt.reshape(G, Sg, d)
    wg = w.reshape(G, Sg, k)
    ig = idx.reshape(G, Sg, k)

    # per-(group, expert) buffer position via cumsum over the k one-hot choices
    dispatch = jnp.zeros((G, Sg, E, C), dtype=xt.dtype)
    combine = jnp.zeros((G, Sg, E, C), dtype=jnp.float32)
    prev_counts = jnp.zeros((G, 1, E), jnp.int32)
    for j in range(k):
        onehot = jax.nn.one_hot(ig[:, :, j], E, dtype=jnp.int32)  # [G,Sg,E]
        pos = jnp.cumsum(onehot, axis=1) - 1 + prev_counts  # position within expert buffer
        prev_counts = prev_counts + onehot.sum(axis=1, keepdims=True)
        keep = (pos < C) & (onehot > 0)
        posc = jnp.clip(pos, 0, C - 1)
        poh = jax.nn.one_hot(posc, C, dtype=xt.dtype) * keep[..., None].astype(xt.dtype)
        dispatch = dispatch + onehot[..., None].astype(xt.dtype) * poh
        combine = combine + poh.astype(jnp.float32) * wg[:, :, j][..., None, None]

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)  # [E,G,C,d]
    xe = constrain(xe, ("experts", "batch", None, None))
    h = jnp.einsum("egcd,edf->egcf", xe, p["w1"].astype(xt.dtype))
    g = jnp.einsum("egcd,edf->egcf", xe, p["w3"].astype(xt.dtype))
    o = jnp.einsum("egcf,efd->egcd", jax.nn.silu(h) * g, p["w2"].astype(xt.dtype))
    o = constrain(o, ("experts", "batch", None, None))
    y = jnp.einsum("egcd,gsec->gsd", o, combine.astype(xt.dtype))
    return y.reshape(T, d)


# -- sort-based dispatch ------------------------------------------------------------------------


def _moe_sort(p, xt, w, idx, cfg):
    """Sort-based dispatch without [T,E,C] one-hots.

    1. flatten (token, choice) pairs, sort by expert id (stable),
    2. compute each pair's slot within its expert (rank - expert start),
    3. scatter token vectors into [E*C, d] padded buffers, run experts,
    4. gather back and combine.
    Memory: O(T*k + E*C*d) — no G×S×E×C tensor.
    """
    T, d = xt.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    C = _capacity(T, cfg)

    flat_e = idx.reshape(-1)  # [T*k]
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stok = flat_tok[order]
    sw = flat_w[order]

    # rank within expert: global rank - start offset of that expert
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    ranks = jnp.arange(T * k) - starts[se]
    keep = ranks < C
    slot = se * C + jnp.clip(ranks, 0, C - 1)

    buf = jnp.zeros((E * C, d), xt.dtype)
    buf = buf.at[jnp.where(keep, slot, E * C - 1)].add(
        xt[stok] * keep[:, None].astype(xt.dtype), mode="drop"
    )
    # NOTE: collisions impossible — (expert, rank) pairs are unique by construction
    xe = buf.reshape(E, C, d)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w1"].astype(xt.dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, p["w3"].astype(xt.dtype))
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, p["w2"].astype(xt.dtype))

    gathered = o.reshape(E * C, d)[slot] * keep[:, None].astype(xt.dtype)
    y = jnp.zeros((T, d), xt.dtype)
    y = y.at[stok].add(gathered * sw[:, None].astype(xt.dtype))
    return y


def moe_ffn(p, x, cfg):
    """x: [B,S,d] -> (y [B,S,d], aux_loss scalar)."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    w, idx, aux = _router(p, xt, cfg)
    if cfg.moe_dispatch == "sort":
        y = _moe_sort(p, xt, w, idx, cfg)
    else:
        y = _moe_einsum(p, xt, w, idx, cfg)
    if cfg.moe_shared_d_ff:
        h = jnp.einsum("td,df->tf", xt, p["sw1"].astype(xt.dtype))
        g = jnp.einsum("td,df->tf", xt, p["sw3"].astype(xt.dtype))
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(h) * g, p["sw2"].astype(xt.dtype))
    return y.reshape(B, S, d), aux
