"""Model assembly: embeddings -> (head blocks, scanned superblocks, tail
blocks) -> final norm -> LM head.

Layers are *scanned*: the superblock pattern (e.g. gemma2's [local, global]
pair, xlstm's 7xmLSTM+1xsLSTM, zamba2's 6xmamba2+shared-attn) is the scan
body and its parameters carry a leading ``n_superblocks`` dim — compile time
is O(pattern), not O(depth).  zamba2's *shared* attention block takes its
parameters from an unscanned slot captured by the scan body (same weights at
every repeat — exactly the architecture's weight sharing).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba2 as m2
from . import ssm_xlstm as xl
from .config import BlockDef, ModelConfig
from .layers import (
    Spec,
    cross_entropy_chunked,
    init_params,
    rms_norm,
    softcap,
    spec_logical,
    spec_shapes,
)
from .moe import moe_ffn, moe_specs
from .sharding import constrain

__all__ = [
    "param_specs",
    "init_model_params",
    "abstract_params",
    "params_logical",
    "forward",
    "loss_fn",
    "init_cache",
    "cache_logical",
    "count_params",
    "count_active_params",
]


# -- parameter spec tree -------------------------------------------------------------------


def _ffn_specs(cfg: ModelConfig, bdef: BlockDef) -> dict:
    d = cfg.d_model
    ff = bdef.d_ff or cfg.d_ff
    std = 1.0 / math.sqrt(d)
    if bdef.ffn == "none":
        return {}
    if bdef.ffn == "moe":
        return {"moe": moe_specs(cfg)}
    if bdef.ffn == "gelu":
        return {
            "w1": Spec((d, ff), ("fsdp_embed", "mlp"), std=std),
            "w2": Spec((ff, d), ("mlp", "fsdp_embed"), std=1.0 / math.sqrt(ff)),
        }
    return {  # swiglu / geglu (gated)
        "w1": Spec((d, ff), ("fsdp_embed", "mlp"), std=std),
        "w3": Spec((d, ff), ("fsdp_embed", "mlp"), std=std),
        "w2": Spec((ff, d), ("mlp", "fsdp_embed"), std=1.0 / math.sqrt(ff)),
    }


def block_specs(cfg: ModelConfig, bdef: BlockDef) -> dict:
    if bdef.kind == "mlstm":
        return xl.mlstm_specs(cfg)
    if bdef.kind == "slstm":
        return xl.slstm_specs(cfg)
    if bdef.kind == "mamba2":
        return m2.mamba2_specs(cfg)
    specs: dict = {"ln1": Spec((cfg.d_model,), ("embed",), init="zeros")}
    specs["attn"] = attn.mla_specs(cfg) if bdef.kind == "mla" else attn.attn_specs(cfg)
    if bdef.ffn != "none":
        specs["ln2"] = Spec((cfg.d_model,), ("embed",), init="zeros")
        specs.update(_ffn_specs(cfg, bdef))
    if bdef.post_norms:
        specs["pn1"] = Spec((cfg.d_model,), ("embed",), init="zeros")
        if bdef.ffn != "none":
            specs["pn2"] = Spec((cfg.d_model,), ("embed",), init="zeros")
    return specs


def param_specs(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    tree: dict = {}
    if cfg.modality == "audio":
        tree["embed"] = Spec(
            (cfg.num_codebooks, V, d), (None, "vocab", "fsdp_embed"), init="embed"
        )
    else:
        tree["embed"] = Spec((V, d), ("vocab", "fsdp_embed"), init="embed")
    if cfg.head_blocks:
        tree["head"] = {
            str(i): block_specs(cfg, b) for i, b in enumerate(cfg.head_blocks)
        }
    tree["stack"] = {
        str(i): (
            {}
            if b.shared
            else jax.tree.map(
                lambda s: s.stacked(cfg.n_superblocks),
                block_specs(cfg, b),
                is_leaf=lambda x: isinstance(x, Spec),
            )
        )
        for i, b in enumerate(cfg.superblock)
    }
    if cfg.tail_blocks:
        tree["tail"] = {
            str(i): block_specs(cfg, b) for i, b in enumerate(cfg.tail_blocks)
        }
    if cfg.has_shared_block:
        tree["shared"] = block_specs(cfg, cfg.shared_block)
    tree["final_norm"] = Spec((d,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        if cfg.modality == "audio":
            tree["out"] = Spec(
                (cfg.num_codebooks, d, V), (None, "embed", "vocab"), std=1.0 / math.sqrt(d)
            )
        else:
            tree["out"] = Spec((d, V), ("embed", "vocab"), std=1.0 / math.sqrt(d))
    return tree


def init_model_params(cfg: ModelConfig, key: jax.Array):
    return init_params(param_specs(cfg), key, _dt(cfg.param_dtype))


def abstract_params(cfg: ModelConfig):
    return spec_shapes(param_specs(cfg), _dt(cfg.param_dtype))


def params_logical(cfg: ModelConfig):
    return spec_logical(param_specs(cfg))


def _dt(name: str):
    return jnp.dtype(name)


def count_params(cfg: ModelConfig) -> int:
    return sum(
        math.prod(s.shape)
        for s in jax.tree.leaves(param_specs(cfg), is_leaf=lambda x: isinstance(x, Spec))
    )


def count_active_params(cfg: ModelConfig) -> int:
    """MoE-aware active parameter count (for MODEL_FLOPS = 6*N_active*D)."""
    total = 0
    specs = param_specs(cfg)
    leaves = jax.tree_util.tree_leaves_with_path(specs, is_leaf=lambda x: isinstance(x, Spec))
    for path, s in leaves:
        n = math.prod(s.shape)
        pstr = jax.tree_util.keystr(path)
        if "moe" in pstr and "router" not in pstr and "sw" not in pstr.split("/")[-1]:
            if cfg.moe_experts:
                n = n * cfg.moe_top_k // cfg.moe_experts
        total += n
    return total


# -- block application ------------------------------------------------------------------------


def _ffn_apply(p, x, cfg, bdef):
    aux = jnp.float32(0.0)
    if bdef.ffn == "none":
        return jnp.zeros_like(x), aux
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if bdef.ffn == "moe":
        y, aux = moe_ffn(p["moe"], h, cfg)
    elif bdef.ffn == "gelu":
        y = jnp.einsum("bsd,df->bsf", h, p["w1"].astype(x.dtype))
        y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(y), p["w2"].astype(x.dtype))
    elif bdef.ffn == "geglu":
        a = jnp.einsum("bsd,df->bsf", h, p["w1"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", h, p["w3"].astype(x.dtype))
        y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(a) * g, p["w2"].astype(x.dtype))
    else:
        from .layers import swiglu

        y = swiglu(h, p["w1"], p["w3"], p["w2"], x.dtype)
    if bdef.post_norms:
        y = rms_norm(y, p["pn2"], cfg.norm_eps)
    return y, aux


def apply_block(bdef: BlockDef, p, x, cfg, positions, cache, cache_index, mode):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    decode = mode == "decode"
    if bdef.kind in ("mlstm", "slstm", "mamba2"):
        fn = {
            ("mlstm", False): xl.mlstm_block_full,
            ("mlstm", True): xl.mlstm_block_decode,
            ("slstm", False): xl.slstm_block_full,
            ("slstm", True): xl.slstm_block_decode,
            ("mamba2", False): m2.mamba2_block_full,
            ("mamba2", True): m2.mamba2_block_decode,
        }[(bdef.kind, decode)]
        if decode:
            out, new_cache = fn(p, x, cfg, bdef, cache, cache_index)
        else:
            out, new_cache = fn(p, x, cfg, bdef, positions, cache=cache, cache_index=cache_index)
        return x + out, new_cache, aux

    # attention-family block
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if bdef.kind == "mla":
        blk = attn.mla_block_decode if decode else attn.mla_block_full
    else:
        blk = attn.attn_block_decode if decode else attn.attn_block_full
    if decode:
        o, new_cache = blk(p["attn"], h, cfg, bdef, cache, cache_index)
    else:
        o, new_cache = blk(
            p["attn"], h, cfg, bdef, positions, cache=cache, cache_index=cache_index
        )
    if bdef.post_norms:
        o = rms_norm(o, p["pn1"], cfg.norm_eps)
    x = x + o
    y, aux = _ffn_apply(p, x, cfg, bdef)
    return x + y, new_cache, aux


# -- cache construction -------------------------------------------------------------------------


def _block_cache(cfg, bdef: BlockDef, batch: int, capacity: int, dtype):
    if bdef.kind == "mlstm":
        return xl.empty_mlstm_state(cfg, batch)
    if bdef.kind == "slstm":
        return xl.empty_slstm_state(cfg, batch)
    if bdef.kind == "mamba2":
        return m2.empty_mamba2_state(cfg, batch)
    if bdef.kind == "mla":
        return attn.empty_mla_cache(cfg, batch, capacity, dtype)
    return attn.empty_kv_cache(cfg, batch, capacity, dtype, window=bdef.window)


def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    """Cache pytree matching the segment structure.  Scanned blocks carry a
    leading n_superblocks dim (each repeat of a shared block still has its own
    cache)."""

    def stacked(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_superblocks, *a.shape)), tree
        )

    cache: dict = {}
    if cfg.head_blocks:
        cache["head"] = {
            str(i): _block_cache(cfg, b, batch, capacity, dtype)
            for i, b in enumerate(cfg.head_blocks)
        }
    cache["stack"] = {
        str(i): stacked(
            _block_cache(
                cfg, cfg.shared_block if b.shared else b, batch, capacity, dtype
            )
        )
        for i, b in enumerate(cfg.superblock)
    }
    if cfg.tail_blocks:
        cache["tail"] = {
            str(i): _block_cache(cfg, b, batch, capacity, dtype)
            for i, b in enumerate(cfg.tail_blocks)
        }
    return cache


_CACHE_LOGICAL = {
    "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
    "c_kv": ("batch", "kv_seq", "kv_lora"),
    "k_rope": ("batch", "kv_seq", "head_dim"),
    "C": ("batch", "heads", "head_dim", None),
    "n": ("batch", "heads", "head_dim"),
    "m": ("batch", "heads"),
    "c": ("batch", "heads", "head_dim"),
    "h": ("batch", "heads", "head_dim"),
    "conv": ("batch", None, "mlp"),
    "state": ("batch", "heads", "head_dim", "state"),
}


def cache_logical(cache) -> Any:
    """Logical axes for every cache leaf (scanned leaves gain 'layers')."""

    def one(path, leaf):
        key = None
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        base = _CACHE_LOGICAL[key]
        # slstm "m"/"n" have 3 dims; mlstm "m" has 2, "n" 3 — trim/extend by rank
        in_stack = any(getattr(p, "key", None) == "stack" for p in path)
        rank = leaf.ndim - (1 if in_stack else 0)
        if len(base) > rank:
            base = base[:rank]
        elif len(base) < rank:
            base = base + (None,) * (rank - len(base))
        return (("layers",) + base) if in_stack else base

    leaves = jax.tree_util.tree_leaves_with_path(cache)
    vals = [one(p, l) for p, l in leaves]
    return jax.tree.unflatten(jax.tree.structure(cache), vals)


# -- embeddings & head --------------------------------------------------------------------------


def embed_tokens(params, cfg: ModelConfig, batch: dict, compute_dtype):
    emb = params["embed"]
    if cfg.modality == "audio":
        # batch["tokens"]: [B, K, S] -> sum of per-codebook embeddings
        codes = batch["tokens"]
        x = jnp.zeros((codes.shape[0], codes.shape[2], cfg.d_model), compute_dtype)
        for kb in range(cfg.num_codebooks):
            x = x + jnp.take(emb[kb], codes[:, kb], axis=0).astype(compute_dtype)
    elif cfg.modality == "vlm":
        tx = jnp.take(emb, batch["tokens"], axis=0).astype(compute_dtype)
        if "image_embeds" in batch:  # decode steps are text-only (image is in cache)
            img = batch["image_embeds"].astype(compute_dtype)  # [B, N_img, d]
            x = jnp.concatenate([img, tx], axis=1)
        else:
            x = tx
    else:
        x = jnp.take(emb, batch["tokens"], axis=0).astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    return x


def _out_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        emb = params["embed"]
        return emb.T if cfg.modality != "audio" else jnp.swapaxes(emb, 1, 2)
    return params["out"]


# -- full forward --------------------------------------------------------------------------------


def _apply_segments(params, cfg, x, positions, cache, cache_index, mode):
    """Run head -> scanned stack -> tail.  Returns (x, new_cache, aux_total)."""
    aux_total = jnp.float32(0.0)
    new_cache: dict = {}

    def run_plain(seg_name, blocks):
        nonlocal x, aux_total
        seg_cache = {}
        for i, b in enumerate(blocks):
            c = cache[seg_name][str(i)] if cache is not None else None
            x_new, c_new, aux = apply_block(
                b, params[seg_name][str(i)], x, cfg, positions, c, cache_index, mode
            )
            x = x_new
            aux_total += aux
            seg_cache[str(i)] = c_new
        if cache is not None:
            new_cache[seg_name] = seg_cache

    if cfg.head_blocks:
        run_plain("head", cfg.head_blocks)

    # scanned superblocks
    stack_params = params["stack"]
    stack_cache = cache["stack"] if cache is not None else None
    shared_p = params.get("shared")

    def body(carry, xs):
        h = constrain(carry, ("batch", "seq", None))
        p_i = xs[0]
        if cfg.bf16_weight_gather:
            # cast matrices to compute dtype while still sharded: the FSDP
            # all-gather then moves bf16 instead of f32 (1-D params stay f32
            # for norm/gate precision)
            compute = _dt(cfg.compute_dtype)
            p_i = jax.tree.map(
                lambda a: a.astype(compute)
                if (a.ndim >= 2 and a.dtype == jnp.float32)
                else a,
                p_i,
            )
        c_i = xs[1] if cache is not None else None
        new_c_i = {}
        aux = jnp.float32(0.0)
        for i, b in enumerate(cfg.superblock):
            p_blk = shared_p if b.shared else p_i[str(i)]
            bdef = cfg.shared_block if b.shared else b
            c_blk = c_i[str(i)] if c_i is not None else None
            h, c_new, a = apply_block(bdef, p_blk, h, cfg, positions, c_blk, cache_index, mode)
            aux += a
            if c_i is not None:
                new_c_i[str(i)] = c_new
        ys = (new_c_i, aux) if cache is not None else aux
        return h, ys

    if mode == "train" and cfg.remat != "none":
        policy = getattr(jax.checkpoint_policies, cfg.remat, None)
        body = jax.checkpoint(body, policy=policy)

    xs = (stack_params, stack_cache) if cache is not None else (stack_params,)
    x, ys = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    if cache is not None:
        new_cache["stack"], auxs = ys
    else:
        auxs = ys
    aux_total += jnp.sum(auxs)

    if cfg.tail_blocks:
        run_plain("tail", cfg.tail_blocks)

    return x, (new_cache if cache is not None else None), aux_total


def forward(params, cfg: ModelConfig, batch, cache=None, cache_index=0, mode="train"):
    """Modes:
    * train:   batch={tokens,labels,...} -> (x_final [B,S,d], aux)
    * prefill: like train but threads a cache through -> (x_final, cache, aux)
    * decode:  batch={tokens [B,1]}, cache, index -> (x_final [B,1,d], cache)
    """
    compute = _dt(cfg.compute_dtype)
    x = embed_tokens(params, cfg, batch, compute)
    x = constrain(x, ("batch", "seq", None))
    B, S = x.shape[:2]
    if mode == "decode":
        positions = None
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)) + cache_index
    x, new_cache, aux = _apply_segments(params, cfg, x, positions, cache, cache_index, mode)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, aux


def logits_from_hidden(params, cfg: ModelConfig, x):
    w = _out_weight(params, cfg)
    if cfg.modality == "audio":
        logits = jnp.einsum(
            "bsd,kdv->bksv", x, w.astype(x.dtype), preferred_element_type=jnp.float32
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", x, w.astype(x.dtype), preferred_element_type=jnp.float32
        )
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits


def loss_fn(params, cfg: ModelConfig, batch):
    """Mean-token CE (+ MoE aux) without materializing full logits."""
    x, _, aux = forward(params, cfg, batch, mode="train")
    w = _out_weight(params, cfg)
    if cfg.modality == "audio":
        losses = []
        for kb in range(cfg.num_codebooks):
            losses.append(
                cross_entropy_chunked(
                    x, w[kb], batch["labels"][:, kb], chunk=cfg.ce_chunk,
                    final_softcap=cfg.final_softcap,
                )
            )
        ce = sum(losses) / cfg.num_codebooks
    else:
        mask = None
        if cfg.modality == "vlm":
            # no loss on image positions
            B, S = batch["labels"].shape
            mask = jnp.concatenate(
                [jnp.zeros((B, cfg.img_tokens)), jnp.ones((B, S - cfg.img_tokens))], axis=1
            ).astype(jnp.float32)
        ce = cross_entropy_chunked(
            x, w, batch["labels"], chunk=cfg.ce_chunk,
            final_softcap=cfg.final_softcap, mask=mask,
        )
    return ce + cfg.moe_aux_coef * aux, {"ce": ce, "aux": aux}
