"""Model / run configuration dataclasses (shared by configs/, launch/, tune/)."""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["BlockDef", "ModelConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One sub-layer slot inside the (scanned) superblock pattern."""

    kind: str = "attn"  # attn | mla | mlstm | slstm | mamba2
    window: int = -1  # sliding-window size for attn (-1 = global)
    ffn: str = "swiglu"  # swiglu | gelu | moe | none
    d_ff: int | None = None  # override cfg.d_ff (e.g. deepseek's dense layer 0)
    post_norms: bool = False  # gemma2 sandwich norms
    shared: bool = False  # zamba2: use the single shared param set


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_layers: int
    superblock: tuple = (BlockDef(),)
    n_superblocks: int = 1
    head_blocks: tuple = ()
    tail_blocks: tuple = ()
    has_shared_block: bool = False
    shared_block: Any = None  # BlockDef for the shared slot

    modality: str = "text"  # text | vlm | audio
    img_tokens: int = 1152  # vlm stub: precomputed patch-embedding count
    num_codebooks: int = 4  # audio

    head_dim: int = 0  # 0 -> d_model // n_heads
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    attn_softcap: float | None = None
    final_softcap: float | None = None
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_d_ff: int = 0
    moe_capacity: float = 1.25
    moe_group: int = 4096  # tokens per dispatch group (einsum mode)
    moe_dispatch: str = "einsum"  # einsum | sort
    moe_aux_coef: float = 0.01
    moe_norm_topk: bool = True

    # MLA
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    ssm_proj_factor: int = 2  # xlstm mLSTM inner width multiple

    # execution
    # q_chunk must divide the sequence-parallel shard (seq/16) in training or
    # chunks straddle shards -> pairwise reshard collectives (§Perf iter. 1)
    q_chunk: int = 256
    prefill_q_chunk: int = 512  # prefill has no SP resharding; bigger = fewer k/v re-reads
    ce_chunk: int = 256
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "nothing_saveable"  # nothing_saveable | dots_saveable | none
    optimizer: str = "adamw"
    scan_unroll: int = 1
    train_microbatch: int = 0  # grad-accumulation slices (0 = off)
    serve_param_dtype: str = "bfloat16"  # serving weights (f32 masters stay on disk)
    serve_fsdp: bool = False  # shard serving weights over batch axes too (235B-class)
    # cast >=2D weights to compute dtype at the top of the layer-scan body so
    # FSDP all-gathers move bf16, not f32 (halves the collective term; §Perf)
    bf16_weight_gather: bool = False
    # Megatron-style attention: shard q heads over "model" during training
    # (requires n_heads % 16 == 0); k/v replicate over model (cheap when
    # n_kv_heads is small). §Perf iteration 3.
    attn_head_shard: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived -------------------------------------------------------------

    def all_blocks(self):
        """(bdef, n_repeats) for parameter counting."""
        out = [(b, 1) for b in self.head_blocks]
        for b in self.superblock:
            out.append((b, self.n_superblocks if not b.shared else 0))
        if self.has_shared_block and self.shared_block is not None:
            out.append((self.shared_block, 1))
        out += [(b, 1) for b in self.tail_blocks]
        return out


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
