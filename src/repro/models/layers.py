"""Shared layers + the parameter-spec machinery.

Every parameter is declared as a :class:`Spec` (shape, logical axes, init).
Spec trees give us, with no weight allocation:

* ``jax.eval_shape``-style abstract params for the multi-pod dry-run,
* NamedShardings via ``models.sharding`` rules,
* deterministic per-path initialization for real runs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = [
    "Spec",
    "spec_shapes",
    "spec_logical",
    "init_params",
    "rms_norm",
    "rope",
    "apply_rope",
    "swiglu",
    "gelu_mlp",
    "softcap",
    "cross_entropy_chunked",
    "Dtypes",
]


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declaration of one parameter tensor."""

    shape: tuple
    logical: tuple
    init: str = "normal"  # normal | zeros | ones | embed
    std: float | None = None  # explicit stddev; default 1/sqrt(fan_in=shape[-2])

    def stacked(self, n: int) -> "Spec":
        """Prepend a scanned-layers dim (fan-in unchanged)."""
        std = self.std
        if std is None and self.init == "normal":
            std = self._default_std()
        return Spec((n, *self.shape), ("layers", *self.logical), self.init, std)

    def _default_std(self) -> float:
        # fan-in = product of all dims except the last (output) dim
        fan_in = max(1, math.prod(self.shape[:-1]))
        return 1.0 / math.sqrt(fan_in)


def spec_shapes(tree, dtype) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        tree,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def spec_logical(tree) -> Any:
    return jax.tree.map(
        lambda s: s.logical, tree, is_leaf=lambda x: isinstance(x, Spec)
    )


def init_params(tree, key: jax.Array, dtype) -> Any:
    """Deterministic per-path init: rng folded with a stable hash of the path."""
    leaves = jax.tree_util.tree_leaves_with_path(tree, is_leaf=lambda x: isinstance(x, Spec))

    def one(path, s: Spec):
        pkey = jax.random.fold_in(key, _path_hash(path))
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        std = s.std
        if std is None:
            std = s._default_std() if s.init == "normal" else 0.02
        if s.init == "embed":
            std = 0.02 if s.std is None else s.std
        return (jax.random.normal(pkey, s.shape, jnp.float32) * std).astype(dtype)

    vals = [one(p, s) for p, s in leaves]
    treedef = jax.tree.structure(tree, is_leaf=lambda x: isinstance(x, Spec))
    return jax.tree.unflatten(treedef, vals)


def _path_hash(path) -> int:
    s = jax.tree_util.keystr(path)
    h = 2166136261
    for ch in s:
        h = ((h ^ ord(ch)) * 16777619) % (2**31)
    return h


@dataclasses.dataclass(frozen=True)
class Dtypes:
    param: Any = jnp.float32
    compute: Any = jnp.bfloat16
    accum: Any = jnp.float32


# -- primitive layers -------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(positions: jax.Array, head_dim: int, theta: float = 10000.0) -> tuple:
    """Rotary embedding tables for given positions [..., S] -> (sin, cos) of
    shape [..., S, head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: [B, S, H, D]; sin/cos: [B, S, D/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :] if sin.ndim == x.ndim - 1 else sin
    cos = cos[..., None, :] if cos.ndim == x.ndim - 1 else cos
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array, compute_dtype) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w1.astype(compute_dtype))
    g = jnp.einsum("...d,df->...f", x, w3.astype(compute_dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(h) * g, w2.astype(compute_dtype))


def gelu_mlp(x: jax.Array, w1: jax.Array, w2: jax.Array, compute_dtype) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w1.astype(compute_dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(h), w2.astype(compute_dtype))


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def cross_entropy_chunked(
    x: jax.Array,
    w_out: jax.Array,
    labels: jax.Array,
    *,
    chunk: int = 256,
    final_softcap: float | None = None,
    mask: jax.Array | None = None,
) -> jax.Array:
    """Mean token cross-entropy without materializing [B, S, V] logits.

    Scans over sequence chunks; each chunk computes logits -> logsumexp ->
    label logit and is rematerialized in the backward pass (``jax.checkpoint``)
    so peak memory is O(B * chunk * V).  This is what makes 256k-vocab
    (gemma2) training fit; the Pallas `crossentropy` kernel is the TPU-native
    fused version of the same contraction.
    """
    B, S, D = x.shape
    V = w_out.shape[-1]
    chunk = min(chunk, S)
    n = S // chunk
    assert S % chunk == 0, (S, chunk)
    xs = x[:, : n * chunk].reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    ms = (
        mask[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
        if mask is not None
        else jnp.ones((n, B, chunk), dtype=jnp.float32)
    )

    @jax.checkpoint
    def chunk_loss(xc, lc, mc):
        logits = jnp.einsum(
            "bsd,dv->bsv", xc, w_out.astype(xc.dtype), preferred_element_type=jnp.float32
        )
        if final_softcap:
            logits = softcap(logits, final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * mc), jnp.sum(mc)

    def body(carry, args):
        tot, cnt = carry
        l, c = chunk_loss(*args)
        return (tot + l, cnt + c), None

    (total, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ls, ms))
    return total / jnp.maximum(count, 1.0)
