"""Monte-Carlo hypervolume counting kernel (Pallas, TPU target).

The many-objective (m > 4) path of ``core/moo.py``'s
``HypervolumeEstimator``: exact WFG recursion blows up combinatorially in m,
so hypervolume and per-point exclusive contributions are estimated by
uniform sampling inside the bounding box ``[min(points), reference]``.  The
kernel streams sample tiles against the full (VMEM-resident) point set and
accumulates, per sample tile,

* ``total``  — how many samples are dominated by >= 1 point
  (``hv ~ box_volume * total / n_samples``), and
* ``excl[i]`` — how many samples are dominated by point ``i`` *alone*
  (``contribution_i ~ box_volume * excl[i] / n_samples`` — the exclusive
  region ``hv(all) - hv(all minus i)`` in expectation).

Counts accumulate as f32 (exact up to 2^24 — far above any sane sample
budget).  Points are padded to a power-of-two count with ``+1e30``
coordinates (they dominate nothing), samples to a block multiple with
``-1e30`` (dominated by nothing), so padding never perturbs a count and XLA
retraces O(log n) times.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ops

__all__ = ["mc_hv_kernel", "mc_hv_counts"]

BIG = 1e30


def mc_hv_kernel(
    pts_ref,  # in: [N, M] full point set (loss orientation)
    smp_ref,  # in: [bs, M] one sample tile
    excl_ref,  # out: [N] exclusive-domination counts
    tot_ref,  # out: [1] dominated-sample count
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        excl_ref[...] = jnp.zeros_like(excl_ref)
        tot_ref[...] = jnp.zeros_like(tot_ref)

    pts = pts_ref[...]
    smp = smp_ref[...]
    # dom[s, p]: point p dominates sample s (<= in every objective; ties
    # count — a measure-zero set under continuous sampling)
    dom = jnp.all(pts[None, :, :] <= smp[:, None, :], axis=2)
    domf = dom.astype(jnp.float32)
    cnt = jnp.sum(domf, axis=1)  # [bs] dominating points per sample
    tot_ref[...] += jnp.sum((cnt > 0.0).astype(jnp.float32)).reshape(1)
    only = (cnt == 1.0).astype(jnp.float32)
    excl_ref[...] += jnp.sum(domf * only[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def _mc_hv_padded(
    points: jax.Array,  # [n_p, m] pow2-padded
    samples: jax.Array,  # [s_p, m] block-multiple-padded
    *,
    block_s: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    ops.bump_trace("pallas.mc_hv")  # traced body: runs once per trace
    n_p, m = points.shape
    ns = samples.shape[0] // block_s
    excl, tot = pl.pallas_call(
        mc_hv_kernel,
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((n_p, m), lambda i: (0, 0)),
            pl.BlockSpec((block_s, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_p,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_p,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(points, samples)
    return excl, tot


def mc_hv_counts(
    points: jax.Array,  # [n, m]
    samples: jax.Array,  # [s, m]
    *,
    block_s: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """``(excl [n] f32, total scalar f32)`` domination counts.

    Padding happens *outside* the jit boundary so the compile cache keys on
    the pow2 bucket, not the raw point count — n in 17..32 shares one trace.
    """
    points = jnp.asarray(points, jnp.float32)
    samples = jnp.asarray(samples, jnp.float32)
    n, m = points.shape
    s = samples.shape[0]
    n_p = ops.pad_pow2_len(n)
    if n_p != n:
        points = jnp.pad(points, ((0, n_p - n), (0, 0)), constant_values=BIG)
    block_s = min(block_s, s)
    s_p = -(-s // block_s) * block_s
    if s_p != s:
        samples = jnp.pad(samples, ((0, s_p - s), (0, 0)), constant_values=-BIG)
    excl, tot = _mc_hv_padded(points, samples, block_s=block_s, interpret=interpret)
    return excl[:n], tot[0]
