"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref", "ssd_ref", "crossentropy_ref", "mlstm_ref",
    "parzen_score_ref", "mc_hv_counts_ref",
]


def attention_ref(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = -1,
    softcap: float = 0.0,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    kf = jnp.repeat(k, group, axis=1)
    vf = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32)
    ) / math.sqrt(D)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]  (post-softplus)
    A: jax.Array,  # [H]  (negative)
    Bm: jax.Array,  # [B, S, G, N]
    Cm: jax.Array,  # [B, S, G, N]
    initial_state: jax.Array | None = None,  # [B, H, P, N]
) -> tuple:
    """Sequential (step-by-step) SSD recurrence — the gold reference."""
    b, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # [B,S,H,N]
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32) * A[None, None, :])  # [B,S,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    state0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, H, P, N), jnp.float32)
    )

    def step(state, args):
        xt, bt, ct, at = args  # [B,H,P], [B,H,N], [B,H,N], [B,H]
        state = state * at[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    final, ys = jax.lax.scan(
        step,
        state0,
        (
            xdt.transpose(1, 0, 2, 3),
            Bh.transpose(1, 0, 2, 3),
            Ch.transpose(1, 0, 2, 3),
            dA.transpose(1, 0, 2),
        ),
    )
    return ys.transpose(1, 0, 2, 3), final  # [B,S,H,P], [B,H,P,N]


def crossentropy_ref(
    x: jax.Array,  # [T, D]
    w: jax.Array,  # [D, V]
    labels: jax.Array,  # [T]
    softcap: float = 0.0,
) -> jax.Array:
    logits = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - ll  # per-token nll


def parzen_score_ref(
    cands: jax.Array,  # [C]
    l_mus: jax.Array, l_sigmas: jax.Array, l_log_norm: jax.Array,  # [Kl]
    g_mus: jax.Array, g_sigmas: jax.Array, g_log_norm: jax.Array,  # [Kg]
) -> jax.Array:
    """TPE acquisition ``log l - log g``: materialized exponent matrices +
    logsumexp per side (oracle for the fused online-accumulation kernel)."""
    cands = jnp.asarray(cands, jnp.float32)

    def side(mus, sigmas, ln):
        mus = jnp.asarray(mus, jnp.float32)
        sigmas = jnp.asarray(sigmas, jnp.float32)
        ln = jnp.asarray(ln, jnp.float32)
        z = (cands[:, None] - mus[None, :]) / sigmas[None, :]
        e = jnp.maximum(-0.5 * z * z + ln[None, :], -1e30)
        return jax.nn.logsumexp(e, axis=1)

    return side(l_mus, l_sigmas, l_log_norm) - side(g_mus, g_sigmas, g_log_norm)


def mc_hv_counts_ref(points: jax.Array, samples: jax.Array) -> tuple:
    """One broadcasted [s, n, m] domination cube (oracle for the tiled
    streaming kernel): ``(excl [n] f32, total scalar f32)``."""
    points = jnp.asarray(points, jnp.float32)
    samples = jnp.asarray(samples, jnp.float32)
    dom = jnp.all(points[None, :, :] <= samples[:, None, :], axis=2)  # [s, n]
    cnt = dom.sum(axis=1)
    excl = (dom & (cnt == 1)[:, None]).sum(axis=0).astype(jnp.float32)
    total = (cnt > 0).sum().astype(jnp.float32)
    return excl, total


def mlstm_ref(q, k, v, logi, logf):
    """Sequential mLSTM recurrence (oracle for the chunked-parallel form).
    q,k,v: [B,S,H,D] (k pre-scaled); logi/logf: [B,S,H]."""
    from repro.models.ssm_xlstm import empty_mlstm_state, mlstm_recurrent_step

    B, S, H, D = q.shape
    state = {
        "C": jnp.zeros((B, H, D, D), jnp.float32),
        "n": jnp.zeros((B, H, D), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
    }
    hs = []
    for t in range(S):
        state, h = mlstm_recurrent_step(
            state, q[:, t : t + 1], k[:, t : t + 1], v[:, t : t + 1],
            logi[:, t : t + 1], logf[:, t : t + 1],
        )
        hs.append(h)
    return jnp.concatenate(hs, axis=1)
