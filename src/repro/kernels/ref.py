"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "ssd_ref", "crossentropy_ref", "mlstm_ref"]


def attention_ref(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = -1,
    softcap: float = 0.0,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    kf = jnp.repeat(k, group, axis=1)
    vf = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32)
    ) / math.sqrt(D)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]  (post-softplus)
    A: jax.Array,  # [H]  (negative)
    Bm: jax.Array,  # [B, S, G, N]
    Cm: jax.Array,  # [B, S, G, N]
    initial_state: jax.Array | None = None,  # [B, H, P, N]
) -> tuple:
    """Sequential (step-by-step) SSD recurrence — the gold reference."""
    b, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # [B,S,H,N]
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32) * A[None, None, :])  # [B,S,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    state0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, H, P, N), jnp.float32)
    )

    def step(state, args):
        xt, bt, ct, at = args  # [B,H,P], [B,H,N], [B,H,N], [B,H]
        state = state * at[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    final, ys = jax.lax.scan(
        step,
        state0,
        (
            xdt.transpose(1, 0, 2, 3),
            Bh.transpose(1, 0, 2, 3),
            Ch.transpose(1, 0, 2, 3),
            dA.transpose(1, 0, 2),
        ),
    )
    return ys.transpose(1, 0, 2, 3), final  # [B,S,H,P], [B,H,P,N]


def crossentropy_ref(
    x: jax.Array,  # [T, D]
    w: jax.Array,  # [D, V]
    labels: jax.Array,  # [T]
    softcap: float = 0.0,
) -> jax.Array:
    logits = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - ll  # per-token nll


def mlstm_ref(q, k, v, logi, logf):
    """Sequential mLSTM recurrence (oracle for the chunked-parallel form).
    q,k,v: [B,S,H,D] (k pre-scaled); logi/logf: [B,S,H]."""
    from repro.models.ssm_xlstm import empty_mlstm_state, mlstm_recurrent_step

    B, S, H, D = q.shape
    state = {
        "C": jnp.zeros((B, H, D, D), jnp.float32),
        "n": jnp.zeros((B, H, D), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
    }
    hs = []
    for t in range(S):
        state, h = mlstm_recurrent_step(
            state, q[:, t : t + 1], k[:, t : t + 1], v[:, t : t + 1],
            logi[:, t : t + 1], logf[:, t : t + 1],
        )
        hs.append(h)
    return jnp.concatenate(hs, axis=1)
