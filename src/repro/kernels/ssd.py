"""Mamba2 SSD chunk-scan kernel (Pallas, TPU target).

Grid (B*H, n_chunks): the chunk axis is innermost and TPU executes it
sequentially, so the inter-chunk state [P, N] lives in VMEM scratch and is
carried across chunk iterations — HBM traffic is exactly one read of
(x, dt, B, C) and one write of y per token, the memory-roofline optimum.
Intra-chunk work is the L x L quadratic contraction on the MXU.

BlockSpecs:
  x/y: [1, L, P]; dt: [1, L]; B/C: [1, L, N]; state scratch: [P, N] f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_kernel", "ssd"]


def ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,  # in
    y_ref, final_ref,  # out
    state_ref,  # scratch [P, N] f32
    *,
    n_chunks: int,
    chunk: int,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # [L, P]
    dt = dt_ref[0].astype(jnp.float32)  # [L]
    A = a_ref[0]  # scalar (this head's A)
    Bm = b_ref[0].astype(jnp.float32)  # [L, N]
    Cm = c_ref[0].astype(jnp.float32)  # [L, N]

    dA = dt * A  # [L] negative log-decay increments
    cum = jnp.cumsum(dA)  # inclusive
    xdt = x * dt[:, None]

    # intra-chunk: y_diag[t] = sum_{s<=t} exp(cum_t - cum_s) (C_t.B_s) xdt_s
    L = chunk
    seg = cum[:, None] - cum[None, :]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    )
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, L]
    y = jax.lax.dot_general(
        cb * decay, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # inter-chunk: y_off[t] = exp(cum_t) * C_t . state_in
    state_in = state_ref[...]  # [P, N]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: state_out = exp(total) * state_in + sum_s exp(total-cum_s) xdt_s B_s^T
    total = cum[-1]
    w = jnp.exp(total - cum)  # [L]
    new_state = jnp.exp(total) * state_in + jax.lax.dot_general(
        xdt * w[:, None], Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_ref[...] = new_state
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finalize():
        final_ref[0] = new_state.astype(final_ref.dtype)


def ssd(
    x: jax.Array,  # [BH, S, P]  (batch*heads folded)
    dt: jax.Array,  # [BH, S]
    A: jax.Array,  # [BH]
    Bm: jax.Array,  # [BH, S, N]
    Cm: jax.Array,  # [BH, S, N]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple:
    BH, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    kernel = functools.partial(ssd_kernel, n_chunks=n, chunk=chunk)
    y, final = pl.pallas_call(
        kernel,
        grid=(BH, n),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, P, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, final
