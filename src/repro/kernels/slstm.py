"""Fused sLSTM scan kernel (Pallas, TPU target) — the xlstm-1.3b hot spot.

The jnp lowering of sLSTM is a ``lax.scan`` over time: every step re-reads
the block-diagonal recurrent weights R [4, H, D, D] from HBM (16.8 MB for
xlstm-1.3b), so one layer of seq-4096 training moves ~69 GB of weight traffic
alone — the dominant term of the worst cell in the roofline table
(xlstm-1.3b x train_4k).  The xLSTM authors hit the same wall and shipped a
fused CUDA kernel; this is the TPU-native equivalent:

* grid over batch tiles; the TIME loop lives INSIDE the kernel,
* R is loaded into VMEM once per batch tile and reused for all S steps,
* the 4 state tensors (c, n, h, m) stay in VMEM registers across steps,
* HBM traffic = inputs [S, Bt, 4d] + outputs [S, Bt, d] + R once.

Per-device traffic for xlstm-1.3b train_4k drops from ~69 GB to ~1.4 GB per
sLSTM layer (measured accounting in EXPERIMENTS.md §Perf iteration 5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["slstm_kernel", "slstm_scan"]


def slstm_kernel(
    u_ref,  # [S, Bt, 4, H, D] input pre-activations (W x + b)
    r_ref,  # [4, H, D, D] recurrent weights
    h_out_ref,  # [S, Bt, H, D]
    c_fin_ref, n_fin_ref, h_fin_ref, m_fin_ref,  # [Bt, H, D] final states
    *,
    seq_len: int,
):
    Bt, H, D = h_out_ref.shape[1:]
    R = r_ref[...].astype(jnp.float32)  # stays in VMEM for the whole tile

    def step(t, state):
        c, n, h, m = state
        u = u_ref[t].astype(jnp.float32)  # [Bt, 4, H, D]
        # recurrent contribution: per-head h @ R_g
        rec = jnp.einsum("bhd,ghde->bghe", h, R, preferred_element_type=jnp.float32)
        z_t = jnp.tanh(u[:, 0] + rec[:, 0])
        i_t = u[:, 1] + rec[:, 1]
        f_t = u[:, 2] + rec[:, 2]
        o_t = jax.nn.sigmoid(u[:, 3] + rec[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_ = jnp.exp(i_t - m_new)
        f_ = jnp.exp(f_t + m - m_new)
        c_new = f_ * c + i_ * z_t
        n_new = jnp.maximum(f_ * n + i_, jnp.exp(-m_new))
        h_new = o_t * c_new / n_new
        h_out_ref[t] = h_new.astype(h_out_ref.dtype)
        return c_new, n_new, h_new, m_new

    zeros = jnp.zeros((Bt, H, D), jnp.float32)
    init = (zeros, zeros, zeros, jnp.full((Bt, H, D), -1e30, jnp.float32))
    c, n, h, m = jax.lax.fori_loop(0, seq_len, step, init)
    c_fin_ref[...] = c
    n_fin_ref[...] = n
    h_fin_ref[...] = h
    m_fin_ref[...] = m


def slstm_scan(
    u: jax.Array,  # [S, B, 4, H, D]
    R: jax.Array,  # [4, H, D, D]
    *,
    batch_tile: int = 8,
    interpret: bool = False,
):
    """Returns (h_seq [S, B, H, D], (c, n, h, m) final states [B, H, D])."""
    S, B, four, H, D = u.shape
    assert four == 4
    bt = min(batch_tile, B)
    while B % bt != 0:
        bt //= 2
    nb = B // bt

    kernel = functools.partial(slstm_kernel, seq_len=S)
    state_spec = pl.BlockSpec((bt, H, D), lambda b: (b, 0, 0))
    h_seq, c, n, h, m = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((S, bt, 4, H, D), lambda b: (0, b, 0, 0, 0)),
            pl.BlockSpec((4, H, D, D), lambda b: (0, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((S, bt, H, D), lambda b: (0, b, 0, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, B, H, D), u.dtype),
            jax.ShapeDtypeStruct((B, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D), jnp.float32),
        ],
        interpret=interpret,
    )(u, R)
    return h_seq, (c, n, h, m)
