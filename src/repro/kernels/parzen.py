"""Fused Parzen-score kernel (Pallas, TPU target).

Computes the TPE acquisition ``log l(x) - log g(x)`` for a batch of
candidates against two truncated-Gaussian mixtures *in one pass*: the kernel
tiles candidates over the grid's first axis and streams both component sets
through the innermost axis with an online (m, l) logsumexp accumulator per
side — the ``(n_cands, n_components)`` exponent matrix the numpy path
materializes never exists.  This is the large-candidate scorer behind the
TPE device engine's score table (``SCORE_TABLE_SIZE`` grid points per call)
and any ask wave with many pending trials.

Component arrays arrive padded to power-of-two buckets (``ops.pad_pow2_vec``
with ``log_norm = -inf``) so XLA retraces O(log n_components) times; the
wrapper additionally pads both mixtures to one common length so a single
grid serves the ``l`` and ``g`` sides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ops

__all__ = ["parzen_score_kernel", "parzen_score"]

NEG_INF = -1e30


def parzen_score_kernel(
    c_ref,  # in: [bc] candidates
    lmu_ref, lsig_ref, lln_ref,  # in: [bk] below-mixture components
    gmu_ref, gsig_ref, gln_ref,  # in: [bk] above-mixture components
    out_ref,  # out: [bc] log l - log g
    lm_ref, ll_ref, gm_ref, gl_ref,  # scratch: [bc] online (m, l) per side
    *,
    n_comp_blocks: int,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        lm_ref[...] = jnp.full_like(lm_ref, NEG_INF)
        ll_ref[...] = jnp.zeros_like(ll_ref)
        gm_ref[...] = jnp.full_like(gm_ref, NEG_INF)
        gl_ref[...] = jnp.zeros_like(gl_ref)

    c = c_ref[...]

    def accumulate(mu_ref, sig_ref, ln_ref, m_ref, l_ref):
        z = (c[:, None] - mu_ref[...][None, :]) / sig_ref[...][None, :]
        # padding components carry log_norm = -inf; clamp to a finite
        # sentinel so the online max shift never mixes infinities
        e = jnp.maximum(-0.5 * z * z + ln_ref[...][None, :], NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(e, axis=1))
        l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
            jnp.exp(e - m_new[:, None]), axis=1
        )
        m_ref[...] = m_new

    accumulate(lmu_ref, lsig_ref, lln_ref, lm_ref, ll_ref)
    accumulate(gmu_ref, gsig_ref, gln_ref, gm_ref, gl_ref)

    @pl.when(ik == n_comp_blocks - 1)
    def _finalize():
        log_l = lm_ref[...] + jnp.log(jnp.maximum(ll_ref[...], 1e-30))
        log_g = gm_ref[...] + jnp.log(jnp.maximum(gl_ref[...], 1e-30))
        out_ref[...] = log_l - log_g


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_k", "interpret")
)
def _parzen_padded(
    cands: jax.Array,  # [C_p] block-multiple-padded
    l_mus, l_sigmas, l_log_norm,  # [K_p] common padded length
    g_mus, g_sigmas, g_log_norm,  # [K_p]
    *,
    block_c: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    ops.bump_trace("pallas.parzen")  # traced body: runs once per trace
    C_p, K_p = cands.shape[0], l_mus.shape[0]
    nc, nk = C_p // block_c, K_p // block_k

    kernel = functools.partial(parzen_score_kernel, n_comp_blocks=nk)
    comp_spec = pl.BlockSpec((block_k,), lambda ic, ik: (ik,))
    cand_spec = pl.BlockSpec((block_c,), lambda ic, ik: (ic,))
    out = pl.pallas_call(
        kernel,
        grid=(nc, nk),
        in_specs=[cand_spec] + [comp_spec] * 6,
        out_specs=cand_spec,
        out_shape=jax.ShapeDtypeStruct((C_p,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_c,), jnp.float32) for _ in range(4)],
        interpret=interpret,
    )(cands, l_mus, l_sigmas, l_log_norm, g_mus, g_sigmas, g_log_norm)
    return out


def parzen_score(
    cands: jax.Array,  # [C]
    l_mus: jax.Array, l_sigmas: jax.Array, l_log_norm: jax.Array,  # [Kl]
    g_mus: jax.Array, g_sigmas: jax.Array, g_log_norm: jax.Array,  # [Kg]
    *,
    block_c: int = 256,
    block_k: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """``log l(cands) - log g(cands)`` as a [C] f32 array.

    All shape normalization (common component length, block-multiple padding)
    happens *outside* the jit boundary, so the compile cache keys on the
    padded shapes: pre-bucketed callers with unequal ``Kl``/``Kg`` (or raw
    callers inside one bucket) share a single trace.
    """

    def prep(x):
        return jnp.asarray(x, jnp.float32)

    cands = prep(cands)
    C = cands.shape[0]
    K = max(l_mus.shape[0], g_mus.shape[0])

    def pad_side(mus, sigmas, ln):
        k = mus.shape[0]
        if k < K:
            mus = jnp.pad(prep(mus), (0, K - k))
            sigmas = jnp.pad(prep(sigmas), (0, K - k), constant_values=1.0)
            ln = jnp.pad(prep(ln), (0, K - k), constant_values=NEG_INF)
            return mus, sigmas, ln
        return prep(mus), prep(sigmas), prep(ln)

    l_side = pad_side(l_mus, l_sigmas, l_log_norm)
    g_side = pad_side(g_mus, g_sigmas, g_log_norm)

    block_c = min(block_c, C)
    block_k = min(block_k, K)
    C_p = -(-C // block_c) * block_c
    K_p = -(-K // block_k) * block_k
    if C_p != C:
        cands = jnp.pad(cands, (0, C_p - C))
    if K_p != K:
        pad = (0, K_p - K)

        def pad_tail(side):
            mus, sigmas, ln = side
            return (
                jnp.pad(mus, pad),
                jnp.pad(sigmas, pad, constant_values=1.0),
                jnp.pad(ln, pad, constant_values=NEG_INF),
            )

        l_side, g_side = pad_tail(l_side), pad_tail(g_side)

    out = _parzen_padded(
        cands, *l_side, *g_side,
        block_c=block_c, block_k=block_k, interpret=interpret,
    )
    return out[:C]
