"""Shared engine policy + jit'd public wrappers around the Pallas kernels.

Two layers live here:

**Engine policy (numpy-only, import-free).**  The sampler stack
(``samplers/tpe.py``, ``core/moo.py``) dispatches every hot reduction through
:func:`resolve_engine`: ``engine="auto"`` picks the device path once the
problem crosses a work threshold (and jax imports), ``"numpy"``/``"jax"``/
``"pallas"`` force a path.  Device inputs are padded to power-of-two buckets
(:func:`pad_pow2_vec` / :func:`pad_pow2_rows`) so the set of shapes XLA ever
sees — and hence the number of retraces — stays logarithmic in the
observation count; the shared trace registry (:func:`bump_trace` /
:func:`trace_count`) is what the retrace-bound tests pin.  Importing this
module does **not** import jax: the policy helpers are pure numpy, and the
jitted wrappers below are materialized lazily via module ``__getattr__``.

**Kernel wrappers (lazy, jax-importing).**  ``use_pallas``: on TPU hardware
the kernels lower natively; on CPU we run ``interpret=True`` (Pallas executes
the kernel body with the XLA interpreter — bit-accurate semantics, no
Mosaic).  The model layers call the pure-jnp chunked implementations by
default and switch to these when ``REPRO_USE_PALLAS=1`` (or on TPU backends).
"""

from __future__ import annotations

import os
import threading

import numpy as np

__all__ = [
    # engine policy
    "MIN_PAD",
    "TPE_JIT_THRESHOLD",
    "DOM_JIT_THRESHOLD",
    "DOM_CPU_CEILING",
    "SCORE_TABLE_SIZE",
    "jax_available",
    "resolve_engine",
    "validate_engine",
    "pad_pow2_len",
    "pad_pow2_vec",
    "pad_pow2_rows",
    "bump_trace",
    "trace_count",
    "reset_traces",
    # kernel wrappers (lazy)
    "flash_attention_op",
    "ssd_op",
    "crossentropy_op",
    "slstm_op",
    "parzen_score_op",
    "mc_hv_counts_op",
    "should_interpret",
    "pallas_enabled",
]

# -- pow2 padding ---------------------------------------------------------------

#: smallest padded bucket — below this every input shares one trace
MIN_PAD = 8


def pad_pow2_len(n: int, min_pad: int = MIN_PAD) -> int:
    """Next power-of-two bucket >= ``n`` (floored at ``min_pad``)."""
    size = min_pad
    while size < n:
        size *= 2
    return size


def pad_pow2_vec(vec: np.ndarray, fill: float, min_pad: int = MIN_PAD) -> np.ndarray:
    """Pad a 1-D array to its power-of-two bucket with ``fill``.

    Device mixtures pad with ``log_norm = -inf`` (or a large-negative finite
    sentinel inside Pallas kernels): padding components contribute
    ``exp(-inf) = 0`` to the logsumexp row sums, so the score is exactly the
    unpadded one while the shape only changes at power-of-two crossings."""
    n = len(vec)
    size = pad_pow2_len(n, min_pad)
    if size == n:
        return vec
    out = np.full(size, fill, dtype=vec.dtype if vec.dtype.kind == "f" else float)
    out[:n] = vec
    return out


def pad_pow2_rows(arr2d: np.ndarray, fill: float, min_pad: int = MIN_PAD) -> np.ndarray:
    """Pad a ``(n, d)`` array to a power-of-two row count with ``fill``."""
    n = len(arr2d)
    size = pad_pow2_len(n, min_pad)
    if size == n:
        return arr2d
    out = np.full((size, arr2d.shape[1]), fill)
    out[:n] = arr2d
    return out


# -- trace registry ---------------------------------------------------------------

_trace_lock = threading.Lock()
_trace_counts: dict[str, int] = {}


def bump_trace(key: str) -> None:
    """Record one XLA trace for ``key`` — call from *inside* the traced
    python body, which runs once per trace, not per call.  Tests pin these
    counts to prove pow2 bucketing bounds retracing."""
    with _trace_lock:
        _trace_counts[key] = _trace_counts.get(key, 0) + 1


def trace_count(key: str) -> int:
    with _trace_lock:
        return _trace_counts.get(key, 0)


def reset_traces(key: "str | None" = None) -> None:
    with _trace_lock:
        if key is None:
            _trace_counts.clear()
        else:
            _trace_counts.pop(key, None)


# -- engine resolution ------------------------------------------------------------

ENGINES = ("auto", "numpy", "jax", "pallas")

#: auto-engine work thresholds: below these the numpy path wins outright
#: (device dispatch overhead dominates).  TPE work = n_candidates x
#: n_components (both estimators); dominance work = n_rows x n_objectives.
TPE_JIT_THRESHOLD = 16384
DOM_JIT_THRESHOLD = 4096
#: the jax dominance reduction materializes the full (n, n, m) comparison
#: cube; off-TPU, cap auto-dispatch so host memory stays bounded
DOM_CPU_CEILING = 64 * 1024
#: grid resolution of the TPE device score table (see samplers/tpe.py)
SCORE_TABLE_SIZE = 4096

_jax_probe: "bool | None" = None


def jax_available() -> bool:
    """Cached jax import probe — one import attempt per process."""
    global _jax_probe
    if _jax_probe is None:
        try:
            import jax  # noqa: F401

            _jax_probe = True
        except Exception:
            _jax_probe = False
    return _jax_probe


def validate_engine(engine: str) -> str:
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    return engine


def resolve_engine(
    engine: str,
    work: int,
    threshold: int,
    ceiling: "int | None" = None,
) -> str:
    """Resolve a requested engine to a concrete path for one call site.

    ``"numpy"`` and explicit ``"jax"``/``"pallas"`` pass through (the caller
    is responsible for falling back — with a logged reason — when jax is
    unavailable).  ``"auto"`` picks the device past ``threshold`` units of
    work (``pallas`` when :func:`pallas_enabled`, else plain jit), staying on
    numpy below it, when jax is missing, or past ``ceiling`` on non-TPU
    backends (memory-bound reductions only)."""
    validate_engine(engine)
    if engine != "auto":
        return engine
    if work < threshold or not jax_available():
        return "numpy"
    if ceiling is not None and work > ceiling:
        import jax

        if jax.default_backend() != "tpu":
            return "numpy"
    return "pallas" if pallas_enabled() else "jax"


# -- pallas / interpret switches (lazy jax import) --------------------------------


def should_interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"


def pallas_enabled() -> bool:
    if os.environ.get("REPRO_USE_PALLAS") == "1":
        return True
    if not jax_available():
        return False
    import jax

    return jax.default_backend() == "tpu"


# -- lazy jitted kernel wrappers --------------------------------------------------
#
# Building these eagerly would make ``import repro.core`` pay the jax import
# (the sampler stack imports this module for the policy helpers alone).  PEP
# 562 module __getattr__ materializes each wrapper on first access and caches
# it in the module dict, so ``from repro.kernels.ops import crossentropy_op``
# keeps working unchanged.


def _build_flash_attention_op():
    import functools

    import jax

    from .flash_attention import flash_attention

    @functools.partial(
        jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k")
    )
    def flash_attention_op(
        q, k, v, causal: bool = True, window: int = -1, softcap: float = 0.0,
        block_q: int = 512, block_k: int = 512,
    ):
        """q: [B, Hq, S, D]; k/v: [B, Hkv, S, D] -> [B, Hq, S, D]."""
        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            block_q=block_q, block_k=block_k, interpret=should_interpret(),
        )

    return flash_attention_op


def _build_ssd_op():
    import functools

    import jax

    from .ssd import ssd

    @functools.partial(jax.jit, static_argnames=("chunk",))
    def ssd_op(x, dt, A, Bm, Cm, chunk: int = 128):
        """Folded-head SSD: x [BH,S,P], dt [BH,S], A [BH], Bm/Cm [BH,S,N]."""
        return ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=should_interpret())

    return ssd_op


def _build_slstm_op():
    import functools

    import jax

    from .slstm import slstm_scan

    @functools.partial(jax.jit, static_argnames=("batch_tile",))
    def slstm_op(u, R, batch_tile: int = 8):
        """Fused sLSTM scan: u [S,B,4,H,D], R [4,H,D,D] -> (h_seq, final states)."""
        return slstm_scan(u, R, batch_tile=batch_tile, interpret=should_interpret())

    return slstm_op


def _build_crossentropy_op():
    import functools

    import jax

    from .crossentropy import fused_crossentropy

    @functools.partial(jax.jit, static_argnames=("softcap", "block_t", "block_v"))
    def crossentropy_op(
        x, w, labels, softcap: float = 0.0, block_t: int = 256, block_v: int = 1024
    ):
        """Fused per-token NLL: x [T,D], w [D,V], labels [T] -> [T] f32."""
        return fused_crossentropy(
            x, w, labels, softcap=softcap, block_t=block_t, block_v=block_v,
            interpret=should_interpret(),
        )

    return crossentropy_op


def _build_parzen_score_op():
    from .parzen import parzen_score

    def parzen_score_op(cands, l_mus, l_sigmas, l_log_norm, g_mus, g_sigmas, g_log_norm):
        """Fused Parzen ``log l - log g`` over candidates (Pallas; interpret
        mode off-TPU).  Component arrays should arrive pow2-padded."""
        return parzen_score(
            cands, l_mus, l_sigmas, l_log_norm, g_mus, g_sigmas, g_log_norm,
            interpret=should_interpret(),
        )

    return parzen_score_op


def _build_mc_hv_counts_op():
    from .hypervolume import mc_hv_counts

    def mc_hv_counts_op(points, samples):
        """MC hypervolume counts (Pallas; interpret mode off-TPU): per-point
        exclusive-domination counts + total dominated count."""
        return mc_hv_counts(points, samples, interpret=should_interpret())

    return mc_hv_counts_op


_LAZY_OPS = {
    "flash_attention_op": _build_flash_attention_op,
    "ssd_op": _build_ssd_op,
    "slstm_op": _build_slstm_op,
    "crossentropy_op": _build_crossentropy_op,
    "parzen_score_op": _build_parzen_score_op,
    "mc_hv_counts_op": _build_mc_hv_counts_op,
}


def __getattr__(name: str):
    builder = _LAZY_OPS.get(name)
    if builder is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    op = builder()
    globals()[name] = op  # cache: __getattr__ fires only on the first miss
    return op
