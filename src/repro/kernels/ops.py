"""jit'd public wrappers around the Pallas kernels.

``use_pallas``: on TPU hardware the kernels lower natively; on CPU we run
``interpret=True`` (Pallas executes the kernel body with the XLA interpreter —
bit-accurate semantics, no Mosaic).  The model layers call the pure-jnp
chunked implementations by default and switch to these when
``REPRO_USE_PALLAS=1`` (or on TPU backends).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .crossentropy import fused_crossentropy
from .flash_attention import flash_attention
from .slstm import slstm_scan
from .ssd import ssd

__all__ = [
    "flash_attention_op",
    "ssd_op",
    "crossentropy_op",
    "slstm_op",
    "should_interpret",
    "pallas_enabled",
]


def should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pallas_enabled() -> bool:
    if os.environ.get("REPRO_USE_PALLAS") == "1":
        return True
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k")
)
def flash_attention_op(
    q, k, v, causal: bool = True, window: int = -1, softcap: float = 0.0,
    block_q: int = 512, block_k: int = 512,
):
    """q: [B, Hq, S, D]; k/v: [B, Hkv, S, D] -> [B, Hq, S, D]."""
    return flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=should_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_op(x, dt, A, Bm, Cm, chunk: int = 128):
    """Folded-head SSD: x [BH,S,P], dt [BH,S], A [BH], Bm/Cm [BH,S,N]."""
    return ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=should_interpret())


@functools.partial(jax.jit, static_argnames=("batch_tile",))
def slstm_op(u, R, batch_tile: int = 8):
    """Fused sLSTM scan: u [S,B,4,H,D], R [4,H,D,D] -> (h_seq, final states)."""
    return slstm_scan(u, R, batch_tile=batch_tile, interpret=should_interpret())


@functools.partial(jax.jit, static_argnames=("softcap", "block_t", "block_v"))
def crossentropy_op(x, w, labels, softcap: float = 0.0, block_t: int = 256, block_v: int = 1024):
    """Fused per-token NLL: x [T,D], w [D,V], labels [T] -> [T] f32."""
    return fused_crossentropy(
        x, w, labels, softcap=softcap, block_t=block_t, block_v=block_v,
        interpret=should_interpret(),
    )
