"""Fused cross-entropy kernel (Pallas, TPU target).

Computes per-token ``logsumexp(x@W) - (x@W)[label]`` without materializing
the [T, V] logits in HBM — the hot spot for 256k-vocab gemma2, where logits
would otherwise dominate the memory-roofline term.

Grid (row_blocks, vocab_blocks), vocab innermost; scratch keeps the online
(m, l) logsumexp state and the label logit per row.  Each step computes one
[block_t, block_v] logits tile on the MXU directly from x and the W tile —
logits never leave VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["crossentropy_kernel", "fused_crossentropy"]

NEG_INF = -1e30


def crossentropy_kernel(
    x_ref, w_ref, label_ref,  # in: [bt, D], [D, bv], [bt]
    nll_ref,  # out: [bt]
    m_ref, l_ref, ll_ref,  # scratch: [bt] each
    *,
    n_vocab_blocks: int,
    block_v: int,
    vocab: int,
    softcap: float,
):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    logits = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bt, bv]
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)

    bt = logits.shape[0]
    v_ids = iv * block_v + jax.lax.broadcasted_iota(jnp.int32, (bt, block_v), 1)
    valid = v_ids < vocab
    logits = jnp.where(valid, logits, NEG_INF)

    # pick the label logit if it lives in this tile
    labels = label_ref[...]
    is_label = v_ids == labels[:, None]
    ll_ref[...] += jnp.sum(jnp.where(is_label, logits, 0.0), axis=1)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(logits - m_new[:, None]), axis=1
    )
    m_ref[...] = m_new

    @pl.when(iv == n_vocab_blocks - 1)
    def _finalize():
        nll_ref[...] = (m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))) - ll_ref[...]


def fused_crossentropy(
    x: jax.Array,  # [T, D]
    w: jax.Array,  # [D, V]
    labels: jax.Array,  # [T] int32
    *,
    softcap: float = 0.0,
    block_t: int = 256,
    block_v: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Per-token negative log-likelihood [T] (f32)."""
    T, D = x.shape
    V = w.shape[1]
    block_t = min(block_t, T)
    block_v = min(block_v, V)
    T_p = -(-T // block_t) * block_t
    V_p = -(-V // block_v) * block_v
    if T_p != T:
        x = jnp.pad(x, ((0, T_p - T), (0, 0)))
        labels = jnp.pad(labels, (0, T_p - T))
    if V_p != V:
        w = jnp.pad(w, ((0, 0), (0, V_p - V)))
    nt, nv = T_p // block_t, V_p // block_v

    kernel = functools.partial(
        crossentropy_kernel,
        n_vocab_blocks=nv, block_v=block_v, vocab=V, softcap=softcap,
    )
    nll = pl.pallas_call(
        kernel,
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda it, iv: (it, 0)),
            pl.BlockSpec((D, block_v), lambda it, iv: (0, iv)),
            pl.BlockSpec((block_t,), lambda it, iv: (it,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda it, iv: (it,)),
        out_shape=jax.ShapeDtypeStruct((T_p,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t,), jnp.float32),
            pltpu.VMEM((block_t,), jnp.float32),
            pltpu.VMEM((block_t,), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, labels)
    return nll[:T]
