"""Flash attention (Pallas, TPU target).

Online-softmax blocked attention with GQA head folding, causal masking,
sliding window, and gemma2-style logit softcap.  Grid is
(batch, q_heads, q_blocks, kv_blocks) with the kv axis innermost — TPU grids
execute sequentially, so the running (m, l, acc) state lives in VMEM scratch
across kv iterations of one q block.

BlockSpec tiling (MXU-aligned):
  q/out: [1, 1, block_q, head_dim]   VMEM
  k/v:   [1, 1, block_k, head_dim]   VMEM (kv head = q head // group)

VMEM budget per step ~ block_q*D + 2*block_k*D + block_q*block_k (+f32 acc);
default 512x512 blocks with D<=256 stays well under 16 MB v5e VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_kernel", "flash_attention"]

NEG_INF = -1e30


def flash_attention_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # scratch (VMEM, persists across kv grid steps)
    *,
    scale: float,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    causal: bool,
    window: int,
    softcap: float,
    seq_len: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # renormalize the running accumulator
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = -1,
    softcap: float = 0.0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    # pad sequence dims to block multiples
    Sq_p = -(-Sq // block_q) * block_q
    Skv_p = -(-Skv // block_k) * block_k
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
    nq = Sq_p // block_q
    nk = Skv_p // block_k

    kernel = functools.partial(
        flash_attention_kernel,
        scale=1.0 / math.sqrt(D),
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=nk,
        causal=causal,
        window=window,
        softcap=softcap,
        seq_len=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, D), q.dtype),
        scratch_shapes=_scratch(block_q, D),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]


def _scratch(block_q: int, D: int):
    """VMEM scratch: running max m, normalizer l, f32 accumulator."""
    from jax.experimental.pallas import tpu as pltpu

    return [
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q, D), jnp.float32),
    ]
