"""Black-box optimization test suite (paper §5.1 / Fig. 9-10).

A reimplementation of the sigopt/evalset-style benchmark family the paper
used [23, 24]: classic continuous test functions over explicit box domains,
several in multiple dimensionalities, for 56 total cases.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

__all__ = ["CASES", "TestCase"]


@dataclasses.dataclass(frozen=True)
class TestCase:
    name: str
    fn: Callable
    bounds: tuple  # ((lo, hi), ...) per dim
    best: float  # known optimum (for regret reporting)

    @property
    def dim(self) -> int:
        return len(self.bounds)


def _mk(name, fn, bounds, best=0.0):
    return TestCase(name, fn, tuple(bounds), best)


def sphere(x):
    return float(np.sum(x * x))


def rosenbrock(x):
    return float(np.sum(100.0 * (x[1:] - x[:-1] ** 2) ** 2 + (1 - x[:-1]) ** 2))


def rastrigin(x):
    return float(10 * len(x) + np.sum(x * x - 10 * np.cos(2 * np.pi * x)))


def ackley(x):
    n = len(x)
    return float(
        -20 * np.exp(-0.2 * np.sqrt(np.sum(x * x) / n))
        - np.exp(np.sum(np.cos(2 * np.pi * x)) / n)
        + 20 + np.e
    )


def griewank(x):
    i = np.arange(1, len(x) + 1)
    return float(np.sum(x * x) / 4000 - np.prod(np.cos(x / np.sqrt(i))) + 1)


def levy(x):
    w = 1 + (x - 1) / 4
    return float(
        np.sin(np.pi * w[0]) ** 2
        + np.sum((w[:-1] - 1) ** 2 * (1 + 10 * np.sin(np.pi * w[:-1] + 1) ** 2))
        + (w[-1] - 1) ** 2 * (1 + np.sin(2 * np.pi * w[-1]) ** 2)
    )


def schwefel(x):
    return float(418.9829 * len(x) - np.sum(x * np.sin(np.sqrt(np.abs(x)))))


def zakharov(x):
    i = np.arange(1, len(x) + 1)
    s = np.sum(0.5 * i * x)
    return float(np.sum(x * x) + s**2 + s**4)


def styblinski_tang(x):
    return float(0.5 * np.sum(x**4 - 16 * x * x + 5 * x) + 39.16617 * len(x))


def dixon_price(x):
    i = np.arange(2, len(x) + 1)
    return float((x[0] - 1) ** 2 + np.sum(i * (2 * x[1:] ** 2 - x[:-1]) ** 2))


def michalewicz(x):
    i = np.arange(1, len(x) + 1)
    return float(-np.sum(np.sin(x) * np.sin(i * x * x / np.pi) ** 20))


def branin(x):
    a, b, c = 1.0, 5.1 / (4 * np.pi**2), 5 / np.pi
    r, s, t = 6.0, 10.0, 1 / (8 * np.pi)
    return float(a * (x[1] - b * x[0] ** 2 + c * x[0] - r) ** 2 + s * (1 - t) * np.cos(x[0]) + s)


def six_hump_camel(x):
    return float(
        (4 - 2.1 * x[0] ** 2 + x[0] ** 4 / 3) * x[0] ** 2
        + x[0] * x[1]
        + (-4 + 4 * x[1] ** 2) * x[1] ** 2
    )


def beale(x):
    return float(
        (1.5 - x[0] + x[0] * x[1]) ** 2
        + (2.25 - x[0] + x[0] * x[1] ** 2) ** 2
        + (2.625 - x[0] + x[0] * x[1] ** 3) ** 2
    )


def goldstein_price(x):
    a = 1 + (x[0] + x[1] + 1) ** 2 * (
        19 - 14 * x[0] + 3 * x[0] ** 2 - 14 * x[1] + 6 * x[0] * x[1] + 3 * x[1] ** 2
    )
    b = 30 + (2 * x[0] - 3 * x[1]) ** 2 * (
        18 - 32 * x[0] + 12 * x[0] ** 2 + 48 * x[1] - 36 * x[0] * x[1] + 27 * x[1] ** 2
    )
    return float(a * b)


def hartmann3(x):
    A = np.array([[3, 10, 30], [0.1, 10, 35], [3, 10, 30], [0.1, 10, 35]])
    P = 1e-4 * np.array(
        [[3689, 1170, 2673], [4699, 4387, 7470], [1091, 8732, 5547], [381, 5743, 8828]]
    )
    alpha = np.array([1.0, 1.2, 3.0, 3.2])
    return float(-np.sum(alpha * np.exp(-np.sum(A * (x - P) ** 2, axis=1))))


def hartmann6(x):
    A = np.array(
        [
            [10, 3, 17, 3.5, 1.7, 8],
            [0.05, 10, 17, 0.1, 8, 14],
            [3, 3.5, 1.7, 10, 17, 8],
            [17, 8, 0.05, 10, 0.1, 14],
        ]
    )
    P = 1e-4 * np.array(
        [
            [1312, 1696, 5569, 124, 8283, 5886],
            [2329, 4135, 8307, 3736, 1004, 9991],
            [2348, 1451, 3522, 2883, 3047, 6650],
            [4047, 8828, 8732, 5743, 1091, 381],
        ]
    )
    alpha = np.array([1.0, 1.2, 3.0, 3.2])
    return float(-np.sum(alpha * np.exp(-np.sum(A * (x - P) ** 2, axis=1))))


def booth(x):
    return float((x[0] + 2 * x[1] - 7) ** 2 + (2 * x[0] + x[1] - 5) ** 2)


def matyas(x):
    return float(0.26 * (x[0] ** 2 + x[1] ** 2) - 0.48 * x[0] * x[1])


def mccormick(x):
    return float(np.sin(x[0] + x[1]) + (x[0] - x[1]) ** 2 - 1.5 * x[0] + 2.5 * x[1] + 1)


def three_hump_camel(x):
    return float(2 * x[0] ** 2 - 1.05 * x[0] ** 4 + x[0] ** 6 / 6 + x[0] * x[1] + x[1] ** 2)


def easom(x):
    return float(-np.cos(x[0]) * np.cos(x[1]) * np.exp(-((x[0] - np.pi) ** 2) - (x[1] - np.pi) ** 2))


def drop_wave(x):
    r2 = x[0] ** 2 + x[1] ** 2
    return float(-(1 + np.cos(12 * np.sqrt(r2))) / (0.5 * r2 + 2))


def sum_squares(x):
    i = np.arange(1, len(x) + 1)
    return float(np.sum(i * x * x))


def rotated_ellipse(x):
    return float(7 * x[0] ** 2 - 6 * np.sqrt(3) * x[0] * x[1] + 13 * x[1] ** 2)


def exponential(x):
    return float(-np.exp(-0.5 * np.sum(x * x)) + 1.0)


def _cases():
    out = []
    for d in (2, 3, 5, 8, 12):
        out.append(_mk(f"sphere_{d}d", sphere, [(-5.12, 5.12)] * d))
        out.append(_mk(f"rosenbrock_{d}d", rosenbrock, [(-2.048, 2.048)] * d))
    for d in (2, 3, 5, 8):
        out.append(_mk(f"rastrigin_{d}d", rastrigin, [(-5.12, 5.12)] * d))
        out.append(_mk(f"ackley_{d}d", ackley, [(-32.77, 32.77)] * d))
        out.append(_mk(f"griewank_{d}d", griewank, [(-600, 600)] * d))
        out.append(_mk(f"levy_{d}d", levy, [(-10, 10)] * d))
        out.append(_mk(f"zakharov_{d}d", zakharov, [(-5, 10)] * d))
    for d in (2, 4, 6):
        out.append(_mk(f"styblinski_{d}d", styblinski_tang, [(-5, 5)] * d, best=0.0))
        out.append(_mk(f"dixonprice_{d}d", dixon_price, [(-10, 10)] * d))
        out.append(_mk(f"sumsquares_{d}d", sum_squares, [(-10, 10)] * d))
    out.append(_mk("schwefel_4d", schwefel, [(-500, 500)] * 4))
    out.append(_mk("michalewicz_2d", michalewicz, [(0, np.pi)] * 2, best=-1.8013))
    out.append(_mk("michalewicz_5d", michalewicz, [(0, np.pi)] * 5, best=-4.6877))
    out.append(_mk("branin", branin, [(-5, 10), (0, 15)], best=0.397887))
    out.append(_mk("sixhump", six_hump_camel, [(-3, 3), (-2, 2)], best=-1.0316))
    out.append(_mk("beale", beale, [(-4.5, 4.5)] * 2))
    out.append(_mk("goldstein", goldstein_price, [(-2, 2)] * 2, best=3.0))
    out.append(_mk("hartmann3", hartmann3, [(0, 1)] * 3, best=-3.86278))
    out.append(_mk("hartmann6", hartmann6, [(0, 1)] * 6, best=-3.32237))
    out.append(_mk("booth", booth, [(-10, 10)] * 2))
    out.append(_mk("matyas", matyas, [(-10, 10)] * 2))
    out.append(_mk("mccormick", mccormick, [(-1.5, 4), (-3, 4)], best=-1.9133))
    out.append(_mk("threehump", three_hump_camel, [(-5, 5)] * 2))
    out.append(_mk("easom", easom, [(-100, 100)] * 2, best=-1.0))
    out.append(_mk("dropwave", drop_wave, [(-5.12, 5.12)] * 2, best=-1.0))
    out.append(_mk("rotatedellipse", rotated_ellipse, [(-500, 500)] * 2))
    out.append(_mk("exponential_4d", exponential, [(-1, 1)] * 4))
    return out


CASES = _cases()
assert len(CASES) == 56, len(CASES)
