"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only SECTION]

Sections: samplers (Fig 9/10), pruning (Fig 11a), distributed (Fig 11b/c, 12),
storage (Table 2 'lightweight'), kernels, roofline (assignment §Roofline).
Prints ``name,us_per_call,derived`` CSV lines at the end for machine parsing.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale budgets (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    sections = ["samplers", "pruning", "moo", "distributed", "storage", "kernels", "roofline"]
    if args.only:
        sections = [s for s in sections if s == args.only]

    csv_rows = [("name", "us_per_call", "derived")]
    t_all = time.time()

    if "samplers" in sections:
        from . import samplers

        print("\n=== §5.1 sampler comparison (paper Fig. 9 / Fig. 10) ===", flush=True)
        budget = dict(n_cases=56, n_trials=80, repeats=30) if args.full else dict(
            n_cases=8, n_trials=30, repeats=3
        )
        t0 = time.time()
        out = samplers.run(**budget)
        dt = time.time() - t0
        for rival, wtl in out["summary"].items():
            csv_rows.append(
                (f"samplers_vs_{rival}", f"{dt*1e6/max(budget['n_cases'],1):.0f}",
                 f"{wtl['wins']}W/{wtl['ties']}T/{wtl['losses']}L")
            )
        mean_tpe_time = sum(
            v for (c, s), v in out["times"].items() if s == "tpe+cmaes"
        ) / max(1, sum(1 for (c, s) in out["times"] if s == "tpe+cmaes"))
        mean_gp_time = sum(v for (c, s), v in out["times"].items() if s == "gp") / max(
            1, sum(1 for (c, s) in out["times"] if s == "gp")
        )
        csv_rows.append(
            ("sampler_time_ratio_gp_vs_tpecmaes", f"{mean_tpe_time*1e6:.0f}",
             f"{mean_gp_time/max(mean_tpe_time,1e-9):.1f}x")
        )
        ask = samplers.ask_throughput(
            n_trials=2000 if args.full else 800, n_params=16,
            n_asks=30 if args.full else 10, n_asks_legacy=5 if args.full else 3,
        )
        csv_rows.append(
            ("sampler_ask_throughput_tpe", f"{ask['vectorized_ms_per_ask']*1e3:.0f}",
             f"speedup={ask['speedup']:.1f}x@{ask['n_trials']}x{ask['n_params']}")
        )

    if "pruning" in sections:
        from . import pruning

        print("\n=== §5.2 pruning speedup (paper Fig. 11a) ===", flush=True)
        budget = dict(budget_seconds=240.0, epochs=32) if args.full else dict(
            budget_seconds=20.0, epochs=12
        )
        rows = pruning.run(**budget)
        for name, r in rows.items():
            csv_rows.append(
                (f"pruning_{name}", f"{budget['budget_seconds']*1e6/max(r['trials'],1):.0f}",
                 f"trials={r['trials']};pruned={r['pruned']};best={r['best_err']:.4f}")
            )

    if "distributed" in sections:
        from . import distributed

        print("\n=== §5.3 distributed scaling (paper Fig. 11b/c, Fig. 12) ===", flush=True)
        budget = dict(n_total_trials=96) if args.full else dict(n_total_trials=32)
        rows = distributed.run(**budget)
        base = rows[list(rows)[0]]["trials_per_sec"]
        for w, r in rows.items():
            csv_rows.append(
                (f"distributed_{w}workers", f"{1e6/max(r['trials_per_sec'],1e-9):.0f}",
                 f"speedup={r['trials_per_sec']/base:.2f}x;best={r['best']:.4f}")
            )

    if "moo" in sections:
        from . import moo as moo_bench

        print("\n=== multi-objective engine (dominance sort + ZDT quality) ===", flush=True)
        dom = moo_bench.dominance_speedup()
        csv_rows.append(
            ("moo_dominance_sort", f"{dom['engine_s']*1e6:.0f}",
             f"speedup={dom['speedup']:.1f}x;front={dom['front_size']}")
        )
        quality = moo_bench.quality_curves(
            n_trials=200 if args.full else 60, cases=("zdt1",)
        )
        for name, per_seed in quality["cases"]["zdt1"].items():
            if not isinstance(per_seed, list):
                continue
            finals = [r["final"] for r in per_seed]
            csv_rows.append(
                (f"moo_zdt1_{name}", "0",
                 f"final_hv_median={sorted(finals)[len(finals)//2]:.4f}")
            )

    if "storage" in sections:
        from . import storage_bench

        print("\n=== storage backends (Table 2 'lightweight' made quantitative) ===", flush=True)
        rows = storage_bench.run()
        for name, r in rows.items():
            if "write_per_sec" not in r:  # ask_latency / moo_worker_storm rows
                continue
            csv_rows.append(
                (f"storage_{name}", f"{1e6/max(r['write_per_sec'],1e-9):.1f}",
                 f"create={r['create_per_sec']:.0f}/s;read={r['full_read_per_sec']:.1f}/s")
            )
        storm = rows.get("moo_worker_storm")
        if storm:
            csv_rows.append(
                ("storage_moo_storm", f"{storm['tell_batch_mean_ms']*1e3:.0f}",
                 f"workers={storm['n_workers']};trials_per_sec={storm['trials_per_sec']:.0f}")
            )

    if "kernels" in sections:
        from . import kernels_bench

        print("\n=== Pallas kernels (interpret-mode vs jnp ref) ===", flush=True)
        rows = kernels_bench.run()
        for name, r in rows.items():
            csv_rows.append((f"kernel_{name}", f"{r['kernel_us']:.0f}", f"ref={r.get('ref_us', 0):.0f}us"))

    if "roofline" in sections:
        results = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
        )
        if os.path.isdir(results) and os.listdir(results):
            sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")))
            from repro.launch.roofline import format_table, load_all

            print("\n=== roofline terms from the multi-pod dry-run (§Roofline) ===", flush=True)
            rows = load_all(results)
            print(format_table(rows, mesh="pod16x16"))
            for r in rows:
                if r["mesh"] != "pod16x16":
                    continue
                csv_rows.append(
                    (f"roofline_{r['arch']}_{r['shape']}",
                     f"{max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s'])*1e6:.0f}",
                     f"bound={r['bottleneck']};fraction={r['roofline_fraction']:.3f}")
                )
        else:
            print("(no dry-run artifacts found; run python -m repro.launch.dryrun --all first)")

    print(f"\ntotal benchmark wall time: {time.time()-t_all:.1f}s\n")
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows[1:]:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
