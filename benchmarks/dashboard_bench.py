"""Live analytics service benchmark (the PR-10 acceptance numbers):

* **full-render vs incremental-poll** — cost of a cold ``/delta`` fetch of
  the whole study vs a poll that ships only K new rows, across study sizes.
  The incremental poll must be O(new trials), not O(study): its latency
  stays flat as n_trials grows while full-render latency climbs.  Idle polls
  (revision unchanged) are timed separately — they cost one revision RPC.
* **fANOVA latency vs n_trials** — wall time of the tree-ensemble
  importance fit as the design matrix grows, with the Spearman baseline.

Emits ``BENCH_dashboard.json`` (uploaded as a CI artifact).
"""

from __future__ import annotations

import json
import time

import repro.core as hpo
from repro.core.analytics import StudyAnalytics
from repro.core.importance import fanova_importances, spearman_importances

try:  # package vs direct-script execution
    from ._meta import bench_metadata
except ImportError:  # pragma: no cover
    from _meta import bench_metadata

__all__ = ["delta_scaling", "fanova_scaling", "main"]


def _seed_study(storage, name: str, n: int):
    s = hpo.create_study(study_name=name, storage=storage,
                         sampler=hpo.RandomSampler(seed=0))
    s.optimize(
        lambda t: (t.suggest_float("x", -3, 3)) ** 2
        + 0.1 * t.suggest_float("y", 0, 1)
        + 0.01 * t.suggest_float("z", 0, 1),
        n_trials=n,
    )
    return s


def _time(fn, repeat: int = 5) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def delta_scaling(sizes=(100, 400, 1600), k_new: int = 10) -> list:
    """Cold full fetch vs K-new-rows incremental poll vs idle poll, per
    study size.  ``incr_ms`` should stay ~flat while ``full_ms`` grows."""
    rows = []
    for n in sizes:
        with hpo.StorageServer(hpo.InMemoryStorage()) as server:
            s = _seed_study(hpo.RemoteStorage(server.url), f"bench{n}", n)
            sa = StudyAnalytics(s)
            full_ms = _time(lambda: sa.delta_rows(-1)) * 1e3
            last = n - 1
            # K fresh tells, then poll for exactly those rows
            s.optimize(lambda t: t.suggest_float("x", -3, 3) ** 2
                       + 0.1 * t.suggest_float("y", 0, 1)
                       + 0.01 * t.suggest_float("z", 0, 1), n_trials=k_new)
            got = sa.delta_rows(last)
            assert len(got["rows"]) == k_new
            incr_ms = _time(lambda: sa.delta_rows(got["last_number"] - k_new)) * 1e3
            # idle: one revision RPC, no trial data
            storage = s._storage
            sid = s._study_id
            idle_ms = _time(lambda: storage.get_trials_revision(sid)) * 1e3
            rows.append(
                {
                    "n_trials": n,
                    "k_new": k_new,
                    "full_ms": round(full_ms, 3),
                    "incr_ms": round(incr_ms, 3),
                    "idle_ms": round(idle_ms, 4),
                    "full_over_incr": round(full_ms / max(incr_ms, 1e-9), 1),
                }
            )
            print(f"  n={n:5d}  full={full_ms:8.2f}ms  incr(k={k_new})="
                  f"{incr_ms:6.2f}ms  idle={idle_ms:6.3f}ms", flush=True)
    return rows


def fanova_scaling(sizes=(50, 200, 800)) -> list:
    """fANOVA tree-ensemble fit wall time vs study size, with the Spearman
    rank-correlation baseline on the same studies."""
    rows = []
    for n in sizes:
        s = _seed_study(None, f"fanova{n}", n)
        fan_ms = _time(lambda: fanova_importances(s), repeat=3) * 1e3
        spear_ms = _time(lambda: spearman_importances(s), repeat=3) * 1e3
        top = max(fanova_importances(s), key=fanova_importances(s).get)
        rows.append(
            {
                "n_trials": n,
                "fanova_ms": round(fan_ms, 2),
                "spearman_ms": round(spear_ms, 2),
                "top_param": top,
            }
        )
        print(f"  n={n:5d}  fanova={fan_ms:8.2f}ms  spearman={spear_ms:6.2f}ms"
              f"  top={top}", flush=True)
    return rows


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description="live analytics service benchmarks")
    ap.add_argument("--out", default="BENCH_dashboard.json")
    ap.add_argument("--sizes", default="100,400,1600")
    args = ap.parse_args(argv)
    sizes = tuple(int(x) for x in args.sizes.split(","))

    print("delta endpoint: full render vs incremental poll", flush=True)
    delta = delta_scaling(sizes)
    print("fANOVA importance fit", flush=True)
    fanova = fanova_scaling(tuple(max(50, n // 2) for n in sizes))

    out = {
        "meta": bench_metadata(),
        "delta_scaling": delta,
        "fanova_scaling": fanova,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
