"""Paper §5.2 (Fig. 11a): pruning speedup on hyperparameter search over a
real iterative training task — plus the **prune-decision throughput**
benchmark for the intermediate-value backbone (vectorized pruner stack vs
the frozen scalar path in ``pruners/_legacy.py``) and the report-path
round-trip count for the fused ``report_and_prune`` storage op.

The paper trains 'simplified AlexNet' (3 conv + 1 fc, 8 hyperparameters) on
SVHN with a 4-hour GPU budget.  The CPU-scale analogue keeps the *shape* of
the experiment: an 8-hyperparameter MLP classifier trained by JAX SGD on a
synthetic SVHN-like task, a fixed wall-clock budget, and four arms:
{random, tpe} x {no pruning, ASHA} + median pruning — measuring trials
explored and best test error vs time.

``python -m benchmarks.pruning --prune-bench`` runs only the throughput +
round-trip measurements and writes ``BENCH_pruning.json`` (CI uploads it as
an artifact next to ``BENCH_samplers.json``).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as hpo
from repro.core.frozen import TrialState

__all__ = [
    "run",
    "make_task",
    "prune_decision_throughput",
    "report_path_round_trips",
    "main",
]


def make_task(seed: int = 0, n: int = 2048, dim: int = 64, classes: int = 10):
    """Synthetic SVHN-stand-in: inputs are random projections of class
    prototypes + noise; learnable by a small MLP, hyperparameter-sensitive."""
    rng = np.random.RandomState(seed)
    protos = rng.randn(classes, dim) * 1.2
    y = rng.randint(0, classes, n)
    x = protos[y] + rng.randn(n, dim) * 1.4
    x = x.astype(np.float32)
    n_tr = int(n * 0.8)
    return (
        (jnp.asarray(x[:n_tr]), jnp.asarray(y[:n_tr])),
        (jnp.asarray(x[n_tr:]), jnp.asarray(y[n_tr:])),
    )


def _train_mlp(trial_or_params, train, test, epochs: int, report=None):
    """8 hyperparameters, mirroring the paper's simplified-AlexNet space."""
    t = trial_or_params
    lr = t.suggest_float("lr", 1e-4, 1.0, log=True)
    momentum = t.suggest_float("momentum", 0.0, 0.99)
    width1 = t.suggest_int("width1", 16, 128, log=True)
    width2 = t.suggest_int("width2", 8, 64, log=True)
    wd = t.suggest_float("weight_decay", 1e-6, 1e-2, log=True)
    bs = t.suggest_categorical("batch_size", [64, 128, 256])
    scale = t.suggest_float("init_scale", 0.3, 3.0, log=True)
    act = t.suggest_categorical("activation", ["relu", "tanh"])

    (xtr, ytr), (xte, yte) = train, test
    dim = xtr.shape[1]
    classes = int(ytr.max()) + 1
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w1": jax.random.normal(k1, (dim, width1)) * scale / np.sqrt(dim),
        "w2": jax.random.normal(k2, (width1, width2)) * scale / np.sqrt(width1),
        "w3": jax.random.normal(k3, (width2, classes)) * scale / np.sqrt(width2),
    }
    vel = jax.tree.map(jnp.zeros_like, params)
    f_act = jax.nn.relu if act == "relu" else jnp.tanh

    def logits_fn(p, x):
        h = f_act(x @ p["w1"])
        h = f_act(h @ p["w2"])
        return h @ p["w3"]

    @jax.jit
    def step(p, v, xb, yb):
        def loss(p):
            lg = logits_fn(p, xb)
            return -jnp.mean(
                jnp.take_along_axis(jax.nn.log_softmax(lg), yb[:, None], axis=1)
            ) + wd * sum(jnp.sum(w * w) for w in jax.tree.leaves(p))

        g = jax.grad(loss)(p)
        v = jax.tree.map(lambda vv, gg: momentum * vv + gg, v, g)
        p = jax.tree.map(lambda pp, vv: pp - lr * vv, p, v)
        return p, v

    @jax.jit
    def err_fn(p):
        return 1.0 - jnp.mean(jnp.argmax(logits_fn(p, xte), axis=1) == yte)

    n = xtr.shape[0]
    for epoch in range(epochs):
        perm = np.random.RandomState(epoch).permutation(n)
        for i in range(0, n - bs + 1, bs):
            idx = perm[i : i + bs]
            params, vel = step(params, vel, xtr[idx], ytr[idx])
        err = float(err_fn(params))
        if report is not None and report(epoch + 1, err):
            raise hpo.TrialPruned()
    return err


def run(budget_seconds: float = 25.0, epochs: int = 16, verbose: bool = True, seed: int = 0):
    train, test = make_task(seed)
    arms = {
        "random": (hpo.RandomSampler(seed=1), hpo.NopPruner()),
        "random+asha": (hpo.RandomSampler(seed=1), hpo.SuccessiveHalvingPruner(1, 2, 0)),
        "tpe": (hpo.TPESampler(seed=1), hpo.NopPruner()),
        "tpe+asha": (hpo.TPESampler(seed=1), hpo.SuccessiveHalvingPruner(1, 2, 0)),
        "tpe+median": (hpo.TPESampler(seed=1), hpo.MedianPruner(n_startup_trials=3)),
    }
    rows = {}
    for name, (sampler, pruner) in arms.items():
        study = hpo.create_study(sampler=sampler, pruner=pruner)

        def objective(trial):
            def report(epoch, err):
                trial.report(err, epoch)
                return trial.should_prune()

            return _train_mlp(trial, train, test, epochs, report)

        study.optimize(objective, timeout=budget_seconds, catch=(Exception,))
        states = [t.state.name for t in study.trials]
        try:
            best = study.best_value
        except ValueError:
            best = float("nan")
        rows[name] = {
            "trials": len(states),
            "pruned": states.count("PRUNED"),
            "complete": states.count("COMPLETE"),
            "best_err": best,
        }
        if verbose:
            print(
                f"[pruning] {name:12s} trials={rows[name]['trials']:4d} "
                f"pruned={rows[name]['pruned']:4d} best_err={best:.4f}",
                flush=True,
            )
    return rows


# -- prune-decision throughput: vectorized stack vs frozen scalar pruners --------


def _seed_pruning_history(study, n_trials: int, n_steps: int, seed: int) -> None:
    """``n_trials`` COMPLETE trials that each reported ``n_steps`` values —
    the peer population every prune decision ranks against."""
    storage, sid = study._storage, study._study_id
    rng = np.random.RandomState(seed)
    for _ in range(n_trials):
        tid = storage.create_new_trial(sid)
        base = float(rng.rand())
        for step in range(1, n_steps + 1):
            storage.set_trial_intermediate_value(
                tid, step, base + 0.1 * float(rng.randn())
            )
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [base])


def _bench_decision(pruner, study, frozen, n_decisions: int) -> float:
    """Median ms per ``prune`` decision (first call warms stores off-clock)."""
    pruner.prune(study, frozen)
    times = []
    for _ in range(n_decisions):
        t0 = time.perf_counter()
        pruner.prune(study, frozen)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def prune_decision_throughput(
    n_trials: int = 1000,
    n_steps: int = 100,
    n_decisions: int = 15,
    n_decisions_legacy: int = 5,
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """Decision latency, vectorized vs frozen-legacy, same seeded history.

    The acceptance bar for the intermediate-value backbone is >= 10x at
    1000 trials x 100 steps.  The target trial reports at a rung-boundary
    step so ASHA/Hyperband actually rank (r=1, eta=2 -> step 64)."""
    from repro.core.pruners._legacy import (
        LegacyHyperbandPruner,
        LegacyMedianPruner,
        LegacySuccessiveHalvingPruner,
    )

    study = hpo.create_study()
    _seed_pruning_history(study, n_trials, n_steps, seed)
    target = study._storage.create_new_trial(study._study_id)
    rng = np.random.RandomState(seed + 1)
    for step in range(1, n_steps + 1):
        study._storage.set_trial_intermediate_value(
            target, step, 0.5 + 0.1 * float(rng.randn())
        )
    frozen = study._storage.get_trial(target)
    rung_step = 1  # largest r=1, eta=2 rung boundary within n_steps, so the
    while rung_step * 2 <= n_steps:  # ASHA/Hyperband rows measure a real
        rung_step *= 2  # ranking decision, not the boundary-check early exit
    at_rung = frozen.copy()
    at_rung.intermediate_values = {
        s: v for s, v in frozen.intermediate_values.items() if s <= rung_step
    }

    # hyperband: steer the target into bracket 0 (its rungs are every power
    # of two), so the row measures a ranking decision at rung_step for any
    # --steps instead of a bracket-boundary early exit
    hb = hpo.HyperbandPruner(1, 64, 2)
    hb_trial = at_rung.copy()
    while hb.bracket_of(hb_trial) != 0:
        hb_trial.number += 1

    pairs = {
        "median": (hpo.MedianPruner(), LegacyMedianPruner(), frozen),
        "asha": (
            hpo.SuccessiveHalvingPruner(1, 2, 0),
            LegacySuccessiveHalvingPruner(1, 2, 0),
            at_rung,
        ),
        "hyperband": (hb, LegacyHyperbandPruner(1, 64, 2), hb_trial),
    }
    out: dict = {"n_trials": n_trials, "n_steps": n_steps, "pruners": {}}
    for name, (new, legacy, trial) in pairs.items():
        new_ms = _bench_decision(new, study, trial, n_decisions)
        legacy_ms = _bench_decision(legacy, study, trial, n_decisions_legacy)
        row = {
            "vectorized_ms_per_decision": new_ms,
            "legacy_ms_per_decision": legacy_ms,
            "speedup": legacy_ms / max(new_ms, 1e-9),
        }
        out["pruners"][name] = row
        if verbose:
            print(
                f"[pruning] {name:10s} decision @ {n_trials} trials x {n_steps} steps: "
                f"vectorized {new_ms:.3f} ms, legacy {legacy_ms:.2f} ms "
                f"-> {row['speedup']:.1f}x",
                flush=True,
            )
    out["min_speedup"] = min(r["speedup"] for r in out["pruners"].values())
    return out


# -- report-path round trips: fused report_and_prune vs the pre-fusion calls -----


def report_path_round_trips(n_steps: int = 16, n_peers: int = 8, verbose: bool = True) -> dict:
    """Wire frames per report+should_prune over ``remote://`` + cache:
    the fused path vs the pre-fusion sequence (set value, refetch own trial,
    re-read all peers for the scalar pruner)."""
    from repro.core.pruners._legacy import LegacyMedianPruner
    from repro.core.storage import CachedStorage, RemoteStorage, StorageServer

    with StorageServer(hpo.InMemoryStorage()) as server:
        remote = RemoteStorage(server.url)
        frames = {"n": 0}
        orig = remote._roundtrip

        def counting(payload):
            frames["n"] += 1
            return orig(payload)

        remote._roundtrip = counting
        study = hpo.create_study(
            study_name="bench", storage=CachedStorage(remote),
            pruner=hpo.MedianPruner(n_startup_trials=1),
        )
        for i in range(n_peers):
            t = study.ask()
            for step in range(1, n_steps + 1):
                t.report(float(i + step), step)
            study.tell(t, float(i))

        # fused: report() carries the decision back on the same frame
        trial = study.ask()
        frames["n"] = 0
        for step in range(1, n_steps + 1):
            trial.report(float(step), step)
            trial.should_prune()
        fused = frames["n"] / n_steps

        # pre-fusion sequence, measured over the same wire
        legacy_pruner = LegacyMedianPruner(n_startup_trials=1)
        trial2 = study.ask()
        storage = study._storage
        frames["n"] = 0
        for step in range(1, n_steps + 1):
            storage.set_trial_intermediate_value(trial2._trial_id, step, float(step))
            frozen = storage.get_trial(trial2._trial_id)
            legacy_pruner.prune(study, frozen)
        unfused = frames["n"] / n_steps
    out = {
        "fused_round_trips_per_step": fused,
        "unfused_round_trips_per_step": unfused,
    }
    if verbose:
        print(
            f"[pruning] report+prune round trips/step: fused {fused:.2f}, "
            f"pre-fusion {unfused:.2f}",
            flush=True,
        )
    return out


def write_bench_json(payload: dict, path: str = "BENCH_pruning.json") -> None:
    try:
        from ._meta import bench_metadata
    except ImportError:  # run as a standalone script, not -m benchmarks.pruning
        from _meta import bench_metadata
    payload.setdefault("meta", bench_metadata())
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[pruning] wrote {path}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="pruning benchmarks")
    ap.add_argument("--prune-bench", action="store_true",
                    help="run only the decision-throughput + round-trip benchmarks")
    ap.add_argument("--trials", type=int, default=1000)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--budget", type=float, default=25.0,
                    help="wall-clock budget for the Fig. 11a-style comparison")
    ap.add_argument("--out", default="BENCH_pruning.json")
    args = ap.parse_args(argv)

    payload: dict = {}
    payload["decision_throughput"] = prune_decision_throughput(
        n_trials=args.trials, n_steps=args.steps
    )
    payload["report_path"] = report_path_round_trips()
    if not args.prune_bench:
        payload["fig11a"] = run(budget_seconds=args.budget)
    write_bench_json(payload, args.out)


if __name__ == "__main__":
    main()
