"""Paper §5.2 (Fig. 11a): pruning speedup on hyperparameter search over a
real iterative training task.

The paper trains 'simplified AlexNet' (3 conv + 1 fc, 8 hyperparameters) on
SVHN with a 4-hour GPU budget.  The CPU-scale analogue keeps the *shape* of
the experiment: an 8-hyperparameter MLP classifier trained by JAX SGD on a
synthetic SVHN-like task, a fixed wall-clock budget, and four arms:
{random, tpe} x {no pruning, ASHA} + median pruning — measuring trials
explored and best test error vs time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as hpo

__all__ = ["run", "make_task"]


def make_task(seed: int = 0, n: int = 2048, dim: int = 64, classes: int = 10):
    """Synthetic SVHN-stand-in: inputs are random projections of class
    prototypes + noise; learnable by a small MLP, hyperparameter-sensitive."""
    rng = np.random.RandomState(seed)
    protos = rng.randn(classes, dim) * 1.2
    y = rng.randint(0, classes, n)
    x = protos[y] + rng.randn(n, dim) * 1.4
    x = x.astype(np.float32)
    n_tr = int(n * 0.8)
    return (
        (jnp.asarray(x[:n_tr]), jnp.asarray(y[:n_tr])),
        (jnp.asarray(x[n_tr:]), jnp.asarray(y[n_tr:])),
    )


def _train_mlp(trial_or_params, train, test, epochs: int, report=None):
    """8 hyperparameters, mirroring the paper's simplified-AlexNet space."""
    t = trial_or_params
    lr = t.suggest_float("lr", 1e-4, 1.0, log=True)
    momentum = t.suggest_float("momentum", 0.0, 0.99)
    width1 = t.suggest_int("width1", 16, 128, log=True)
    width2 = t.suggest_int("width2", 8, 64, log=True)
    wd = t.suggest_float("weight_decay", 1e-6, 1e-2, log=True)
    bs = t.suggest_categorical("batch_size", [64, 128, 256])
    scale = t.suggest_float("init_scale", 0.3, 3.0, log=True)
    act = t.suggest_categorical("activation", ["relu", "tanh"])

    (xtr, ytr), (xte, yte) = train, test
    dim = xtr.shape[1]
    classes = int(ytr.max()) + 1
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w1": jax.random.normal(k1, (dim, width1)) * scale / np.sqrt(dim),
        "w2": jax.random.normal(k2, (width1, width2)) * scale / np.sqrt(width1),
        "w3": jax.random.normal(k3, (width2, classes)) * scale / np.sqrt(width2),
    }
    vel = jax.tree.map(jnp.zeros_like, params)
    f_act = jax.nn.relu if act == "relu" else jnp.tanh

    def logits_fn(p, x):
        h = f_act(x @ p["w1"])
        h = f_act(h @ p["w2"])
        return h @ p["w3"]

    @jax.jit
    def step(p, v, xb, yb):
        def loss(p):
            lg = logits_fn(p, xb)
            return -jnp.mean(
                jnp.take_along_axis(jax.nn.log_softmax(lg), yb[:, None], axis=1)
            ) + wd * sum(jnp.sum(w * w) for w in jax.tree.leaves(p))

        g = jax.grad(loss)(p)
        v = jax.tree.map(lambda vv, gg: momentum * vv + gg, v, g)
        p = jax.tree.map(lambda pp, vv: pp - lr * vv, p, v)
        return p, v

    @jax.jit
    def err_fn(p):
        return 1.0 - jnp.mean(jnp.argmax(logits_fn(p, xte), axis=1) == yte)

    n = xtr.shape[0]
    for epoch in range(epochs):
        perm = np.random.RandomState(epoch).permutation(n)
        for i in range(0, n - bs + 1, bs):
            idx = perm[i : i + bs]
            params, vel = step(params, vel, xtr[idx], ytr[idx])
        err = float(err_fn(params))
        if report is not None and report(epoch + 1, err):
            raise hpo.TrialPruned()
    return err


def run(budget_seconds: float = 25.0, epochs: int = 16, verbose: bool = True, seed: int = 0):
    train, test = make_task(seed)
    arms = {
        "random": (hpo.RandomSampler(seed=1), hpo.NopPruner()),
        "random+asha": (hpo.RandomSampler(seed=1), hpo.SuccessiveHalvingPruner(1, 2, 0)),
        "tpe": (hpo.TPESampler(seed=1), hpo.NopPruner()),
        "tpe+asha": (hpo.TPESampler(seed=1), hpo.SuccessiveHalvingPruner(1, 2, 0)),
        "tpe+median": (hpo.TPESampler(seed=1), hpo.MedianPruner(n_startup_trials=3)),
    }
    rows = {}
    for name, (sampler, pruner) in arms.items():
        study = hpo.create_study(sampler=sampler, pruner=pruner)

        def objective(trial):
            def report(epoch, err):
                trial.report(err, epoch)
                return trial.should_prune()

            return _train_mlp(trial, train, test, epochs, report)

        study.optimize(objective, timeout=budget_seconds, catch=(Exception,))
        states = [t.state.name for t in study.trials]
        try:
            best = study.best_value
        except ValueError:
            best = float("nan")
        rows[name] = {
            "trials": len(states),
            "pruned": states.count("PRUNED"),
            "complete": states.count("COMPLETE"),
            "best_err": best,
        }
        if verbose:
            print(
                f"[pruning] {name:12s} trials={rows[name]['trials']:4d} "
                f"pruned={rows[name]['pruned']:4d} best_err={best:.4f}",
                flush=True,
            )
    return rows
