"""Multi-objective engine benchmarks (beyond-paper; ISSUE-5 acceptance).

Two measurements, emitted as ``BENCH_moo.json`` (CI uploads it as an
artifact next to the sampler/pruning benches):

* **dominance-sort speedup** — ``Study.best_trials`` on the columnar engine
  (one vectorized dominance reduction over the observation store's values
  matrix) vs the frozen pure-Python pairwise loop
  (``repro.core.study._pairwise_best_trials``), at 2k trials x 3 objectives,
  parity-checked before timing.  Acceptance: >= 20x.
* **hypervolume-vs-random curves** — final (and per-wave) dominated
  hypervolume on ZDT1/ZDT2 for ``nsga2`` / ``motpe`` / ``random`` across
  seeds.  Acceptance: both engine samplers dominate random on final
  hypervolume for 3/3 seeds on ZDT1 @ 200 trials.

``python -m benchmarks.moo --moo-bench`` runs a CI-scaled version (fewer
trials per curve); ``--full`` restores the acceptance-scale budgets.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import repro.core as hpo
from repro.core import moo
from repro.core.study import _pairwise_best_trials

__all__ = ["dominance_speedup", "zdt", "quality_curves", "main"]


# -- dominance-sort speedup ----------------------------------------------------------


def _seeded_mo_study(n_trials: int, n_objectives: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    study = hpo.create_study(
        directions=["minimize"] * n_objectives, sampler=hpo.RandomSampler(seed=seed)
    )
    trials = study.ask(n_trials)
    study.tell_batch(
        [(t, rng.uniform(size=n_objectives).tolist()) for t in trials]
    )
    return study


def dominance_speedup(
    n_trials: int = 2000, n_objectives: int = 3, repeats: int = 3, verbose: bool = True
) -> dict:
    """Engine ``best_trials`` vs the frozen pairwise loop on one identical
    history.  The pairwise loop is timed once (it is the slow side); the
    engine is timed over ``repeats`` runs with the store warm — matching how
    each is actually used (the store persists across asks, the loop
    re-walked everything every call)."""
    study = _seeded_mo_study(n_trials, n_objectives)
    completed = study.get_trials(deepcopy=False)

    t0 = time.perf_counter()
    reference = _pairwise_best_trials(completed, study.directions)
    legacy_s = time.perf_counter() - t0

    study.observations()  # warm the columnar store outside the timed region
    engine = study.best_trials
    assert [t.number for t in engine] == [t.number for t in reference], "parity!"

    # time only the engine's dominance work (pareto_front: store reads + one
    # vectorized reduction); best_trials adds an O(n) FrozenTrial filter that
    # both sides share, so the front computation is the honest comparison
    t0 = time.perf_counter()
    for _ in range(repeats):
        study.pareto_front()
    engine_s = (time.perf_counter() - t0) / repeats

    out = {
        "n_trials": n_trials,
        "n_objectives": n_objectives,
        "front_size": len(reference),
        "pairwise_s": legacy_s,
        "engine_s": engine_s,
        "speedup": legacy_s / max(engine_s, 1e-12),
    }
    if verbose:
        print(
            f"[moo] dominance sort @{n_trials}x{n_objectives}: pairwise "
            f"{legacy_s * 1e3:8.1f}ms engine {engine_s * 1e3:8.3f}ms -> "
            f"{out['speedup']:8.1f}x (front {out['front_size']})",
            flush=True,
        )
    return out


# -- hypervolume-vs-random quality curves ---------------------------------------------


def zdt(which: str, d: int = 12):
    """ZDT1 (convex front) / ZDT2 (concave front) objectives on [0,1]^d."""

    def objective(trial):
        x = [trial.suggest_float(f"x{i}", 0, 1) for i in range(d)]
        f1 = x[0]
        g = 1.0 + 9.0 * sum(x[1:]) / (d - 1)
        if which == "zdt1":
            f2 = g * (1.0 - np.sqrt(f1 / g))
        elif which == "zdt2":
            f2 = g * (1.0 - (f1 / g) ** 2)
        else:
            raise ValueError(which)
        return [f1, f2]

    return objective


#: fixed reference point shared by every sampler/curve so hypervolumes compare
_REF = np.asarray([1.1, 11.0])


def _make(name: str, seed: int):
    if name == "nsga2":
        return hpo.NSGAIISampler(population_size=20, seed=seed)
    if name == "motpe":
        return hpo.TPESampler(seed=seed, n_startup_trials=20, multi_objective=True)
    if name == "random":
        return hpo.RandomSampler(seed=seed)
    raise ValueError(name)


def quality_curves(
    cases=("zdt1", "zdt2"),
    samplers=("nsga2", "motpe", "random"),
    n_trials: int = 200,
    seeds=(0, 1, 2),
    curve_every: int = 25,
    verbose: bool = True,
) -> dict:
    """Per (case, sampler, seed): the dominated-hypervolume curve sampled
    every ``curve_every`` trials plus the final value, all against the fixed
    reference point so samplers are directly comparable."""
    out: dict = {"reference_point": _REF.tolist(), "n_trials": n_trials, "cases": {}}
    for case in cases:
        objective = zdt(case)
        rows: dict = {}
        for name in samplers:
            per_seed = []
            for seed in seeds:
                study = hpo.create_study(
                    directions=["minimize", "minimize"], sampler=_make(name, seed)
                )
                curve = []
                done = 0
                while done < n_trials:
                    step = min(curve_every, n_trials - done)
                    study.optimize(objective, n_trials=step)
                    done += step
                    V, _ = study.pareto_front()
                    curve.append(moo.hypervolume(np.asarray(V), _REF))
                per_seed.append({"seed": seed, "curve": curve, "final": curve[-1]})
                if verbose:
                    print(
                        f"[moo] {case:6s} {name:7s} seed={seed} "
                        f"final_hv={curve[-1]:9.5f}",
                        flush=True,
                    )
            rows[name] = per_seed
        out["cases"][case] = rows
        if "random" in rows:
            rand_final = [r["final"] for r in rows["random"]]
            for name in samplers:
                if name == "random":
                    continue
                wins = sum(
                    r["final"] > rf
                    for r, rf in zip(rows[name], rand_final)
                )
                out["cases"][case][f"{name}_beats_random"] = f"{wins}/{len(rand_final)}"
                if verbose:
                    print(
                        f"[moo] {case:6s} {name} beats random on final "
                        f"hypervolume: {wins}/{len(rand_final)} seeds",
                        flush=True,
                    )
    return out


# -- CLI ------------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="multi-objective engine benchmarks")
    ap.add_argument("--moo-bench", action="store_true",
                    help="run the dominance-speedup + quality benchmarks")
    ap.add_argument("--full", action="store_true",
                    help="acceptance-scale budgets (200 trials/curve)")
    ap.add_argument("--trials", type=int, default=None,
                    help="override trials per quality curve")
    ap.add_argument("--out", default="BENCH_moo.json")
    args = ap.parse_args(argv)

    try:
        from ._meta import bench_metadata
    except ImportError:  # run as a standalone script, not -m benchmarks.moo
        from _meta import bench_metadata

    n_trials = args.trials if args.trials is not None else (200 if args.full else 60)
    payload = {"dominance": dominance_speedup(), "meta": bench_metadata()}
    if n_trials > 0:
        payload["quality"] = quality_curves(n_trials=n_trials)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[moo] wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
