"""Paper §5.3 (Fig. 11b/c + Fig. 12): distributed-optimization scaling.

Measures, for 1/2/4/8 workers sharing one storage:
* trials/second (throughput scaling — Fig. 11b's x-axis is wall time),
* best-value-vs-#trials curves (Fig. 11c's invariance claim:
  parallelization does not change per-trial efficiency),
* with and without ASHA pruning (Fig. 12).

Workers are real processes against sqlite (the paper's Fig. 7 deployment).
The objective simulates a training run (sleep-per-epoch) so that trial
latency — not Python overhead — dominates, matching the paper's setting.
"""

from __future__ import annotations

import time

import numpy as np

import repro.core as hpo

__all__ = ["run", "objective_sim"]


def objective_sim(trial):
    """Simulated learning-curve objective (epoch sleep + deterministic curve)."""
    lr = trial.suggest_float("lr", 1e-4, 1.0, log=True)
    width = trial.suggest_int("width", 8, 256, log=True)
    quality = abs(np.log10(lr) + 2.0) * 0.35 + abs(np.log2(width) - 6) * 0.08
    for epoch in range(1, 9):
        err = 0.9 * np.exp(-epoch / 3.0) + 0.08 + quality * (1 - np.exp(-epoch / 4.0))
        time.sleep(0.01)  # simulated epoch cost
        trial.report(err, epoch)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return err


def _best_curve(trials) -> list:
    best, out = float("inf"), []
    for t in sorted(trials, key=lambda t: t.number):
        if t.values is not None and np.isfinite(t.values[0]):
            best = min(best, t.values[0])
        out.append(best)
    return out


def run(worker_counts=(1, 2, 4, 8), n_total_trials: int = 48, pruner: str = "asha",
        tmpdir: str = "/tmp/repro_dist_bench", verbose: bool = True):
    import os
    import shutil

    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)

    rows = {}
    for n_workers in worker_counts:
        url = f"sqlite:///{tmpdir}/bench_{n_workers}.db"
        study_name = f"scale_{n_workers}"
        hpo.create_study(study_name=study_name, storage=url)
        per_worker = n_total_trials // n_workers
        dur = hpo.run_workers(
            n_workers, url, study_name, objective_sim,
            n_trials_per_worker=per_worker,
            sampler_factory=lambda: hpo.TPESampler(),
            pruner_factory=(
                (lambda: hpo.SuccessiveHalvingPruner(1, 2, 0)) if pruner == "asha" else None
            ),
        )
        study = hpo.load_study(study_name, url)
        trials = study.trials
        states = [t.state.name for t in trials]
        curve = _best_curve(trials)
        rows[n_workers] = {
            "seconds": dur,
            "trials": len(trials),
            "trials_per_sec": len(trials) / dur,
            "pruned": states.count("PRUNED"),
            "best": study.best_value,
            "best_at_half": curve[len(curve) // 2] if curve else float("nan"),
        }
        if verbose:
            r = rows[n_workers]
            print(
                f"[distributed] workers={n_workers} wall={r['seconds']:6.2f}s "
                f"trials={r['trials']} ({r['trials_per_sec']:.1f}/s) "
                f"pruned={r['pruned']} best={r['best']:.4f}",
                flush=True,
            )

    # Fig. 11c invariance: best-after-N-trials should not degrade with workers
    base = rows[worker_counts[0]]["best"]
    for w in worker_counts[1:]:
        ratio = rows[w]["best"] / max(base, 1e-9)
        rows[w]["efficiency_vs_serial"] = ratio
    return rows
