"""Kernel microbenchmarks: interpret-mode correctness timing vs jnp reference.

On CPU these are *correctness/overhead* numbers (Pallas interpret mode), not
TPU wall times — the TPU roofline for the kernels is derived analytically in
EXPERIMENTS.md §Perf (VMEM-resident traffic accounting).

``python -m benchmarks.kernels_bench`` writes ``BENCH_kernels.json`` (CI
uploads it as an artifact) with one row per kernel, including the sampler
engine's Parzen-score and Monte-Carlo hypervolume kernels and their max
absolute deviation from the jnp oracles.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.hypervolume import mc_hv_counts
from repro.kernels.ops import crossentropy_op, flash_attention_op, ssd_op
from repro.kernels.parzen import parzen_score

__all__ = ["run", "main"]


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6  # us


def _max_err(a, b) -> float:
    return float(jnp.max(jnp.abs(jnp.asarray(a) - jnp.asarray(b))))


def run(verbose: bool = True):
    rng = np.random.RandomState(0)
    rows = {}

    q = jnp.asarray(rng.randn(1, 4, 256, 64).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    t_kernel = _time(lambda *a: flash_attention_op(*a, block_q=64, block_k=64), q, k, v)
    t_ref = _time(lambda *a: ref.attention_ref(*a), q, k, v)
    rows["flash_attention_256"] = {"kernel_us": t_kernel, "ref_us": t_ref}

    x = jnp.asarray(rng.randn(8, 256, 32).astype(np.float32))
    dt = jnp.abs(jnp.asarray(rng.randn(8, 256).astype(np.float32))) * 0.5
    A = -jnp.abs(jnp.asarray(rng.randn(8).astype(np.float32)))
    Bm = jnp.asarray(rng.randn(8, 256, 16).astype(np.float32))
    Cm = jnp.asarray(rng.randn(8, 256, 16).astype(np.float32))
    rows["ssd_256"] = {
        "kernel_us": _time(lambda *a: ssd_op(*a, chunk=64), x, dt, A, Bm, Cm),
    }

    xe = jnp.asarray(rng.randn(512, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 4096).astype(np.float32) * 0.05)
    labels = jnp.asarray(rng.randint(0, 4096, (512,)).astype(np.int32))
    rows["fused_ce_512x4096"] = {
        "kernel_us": _time(lambda *a: crossentropy_op(*a, block_t=128, block_v=512), xe, w, labels),
        "ref_us": _time(lambda *a: ref.crossentropy_ref(*a), xe, w, labels),
    }

    # Parzen log l - log g: the TPE device score table's shape (4096-point
    # grid vs two ~1k-component mixtures)
    C, K = 4096, 1024
    cands = jnp.asarray(rng.uniform(-3, 3, C).astype(np.float32))
    mixes = []
    for _ in range(2):
        mus = rng.uniform(-3, 3, K).astype(np.float32)
        sigmas = rng.uniform(0.05, 1.0, K).astype(np.float32)
        ln = (np.log(np.full(K, 1.0 / K)) - np.log(sigmas)).astype(np.float32)
        mixes += [jnp.asarray(mus), jnp.asarray(sigmas), jnp.asarray(ln)]
    pz = lambda *a: parzen_score(*a, interpret=True)
    rows["parzen_score_4096x1024"] = {
        "kernel_us": _time(pz, cands, *mixes),
        "ref_us": _time(lambda *a: ref.parzen_score_ref(*a), cands, *mixes),
        "max_err": _max_err(pz(cands, *mixes), ref.parzen_score_ref(cands, *mixes)),
    }

    # MC hypervolume counts: a 64-point 6-objective front vs 8192 samples
    pts = jnp.asarray(rng.uniform(0, 1, (64, 6)).astype(np.float32))
    smp = jnp.asarray(rng.uniform(0, 1.1, (8192, 6)).astype(np.float32))
    hv = lambda *a: mc_hv_counts(*a, interpret=True)
    excl_k, tot_k = hv(pts, smp)
    excl_r, tot_r = ref.mc_hv_counts_ref(pts, smp)
    rows["mc_hv_64x6x8192"] = {
        "kernel_us": _time(lambda *a: hv(*a)[0], pts, smp),
        "ref_us": _time(lambda *a: ref.mc_hv_counts_ref(*a)[0], pts, smp),
        "max_err": max(_max_err(excl_k, excl_r), _max_err(tot_k, tot_r)),
    }

    if verbose:
        for name, r in rows.items():
            parts = " ".join(f"{k}={v:9.4g}" for k, v in r.items())
            print(f"[kernels] {name:22s} {parts}", flush=True)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="kernel microbenchmarks")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)
    rows = run()
    try:
        from ._meta import bench_metadata
    except ImportError:  # run as a standalone script, not -m benchmarks.kernels_bench
        from _meta import bench_metadata
    payload = {"kernels": rows, "meta": bench_metadata()}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[kernels] wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
