"""Kernel microbenchmarks: interpret-mode correctness timing vs jnp reference.

On CPU these are *correctness/overhead* numbers (Pallas interpret mode), not
TPU wall times — the TPU roofline for the kernels is derived analytically in
EXPERIMENTS.md §Perf (VMEM-resident traffic accounting).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import crossentropy_op, flash_attention_op, ssd_op

__all__ = ["run"]


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6  # us


def run(verbose: bool = True):
    rng = np.random.RandomState(0)
    rows = {}

    q = jnp.asarray(rng.randn(1, 4, 256, 64).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    t_kernel = _time(lambda *a: flash_attention_op(*a, block_q=64, block_k=64), q, k, v)
    t_ref = _time(lambda *a: ref.attention_ref(*a), q, k, v)
    rows["flash_attention_256"] = {"kernel_us": t_kernel, "ref_us": t_ref}

    x = jnp.asarray(rng.randn(8, 256, 32).astype(np.float32))
    dt = jnp.abs(jnp.asarray(rng.randn(8, 256).astype(np.float32))) * 0.5
    A = -jnp.abs(jnp.asarray(rng.randn(8).astype(np.float32)))
    Bm = jnp.asarray(rng.randn(8, 256, 16).astype(np.float32))
    Cm = jnp.asarray(rng.randn(8, 256, 16).astype(np.float32))
    rows["ssd_256"] = {
        "kernel_us": _time(lambda *a: ssd_op(*a, chunk=64), x, dt, A, Bm, Cm),
    }

    xe = jnp.asarray(rng.randn(512, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 4096).astype(np.float32) * 0.05)
    labels = jnp.asarray(rng.randint(0, 4096, (512,)).astype(np.int32))
    rows["fused_ce_512x4096"] = {
        "kernel_us": _time(lambda *a: crossentropy_op(*a, block_t=128, block_v=512), xe, w, labels),
        "ref_us": _time(lambda *a: ref.crossentropy_ref(*a), xe, w, labels),
    }

    if verbose:
        for name, r in rows.items():
            parts = " ".join(f"{k}={v:9.1f}" for k, v in r.items())
            print(f"[kernels] {name:22s} {parts}", flush=True)
    return rows
