"""Storage backend throughput (beyond-paper; Table-2 'lightweight' claim made
quantitative): ops/sec per backend for the three dominant operations."""

from __future__ import annotations

import time

import repro.core as hpo
from repro.core.distributions import FloatDistribution
from repro.core.frozen import StudyDirection, TrialState

__all__ = ["run"]


def _bench(storage, n_trials: int = 200):
    sid = storage.create_new_study([StudyDirection.MINIMIZE], "bench")
    t0 = time.time()
    tids = [storage.create_new_trial(sid) for _ in range(n_trials)]
    t_create = time.time() - t0

    t0 = time.time()
    for tid in tids:
        storage.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
        storage.set_trial_intermediate_value(tid, 1, 1.0)
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [1.0])
    t_write = time.time() - t0

    t0 = time.time()
    for _ in range(20):
        trials = storage.get_all_trials(sid, deepcopy=False)
    t_read = time.time() - t0
    assert len(trials) == n_trials
    return {
        "create_per_sec": n_trials / max(t_create, 1e-9),
        "write_per_sec": 3 * n_trials / max(t_write, 1e-9),
        "full_read_per_sec": 20 / max(t_read, 1e-9),
    }


def run(tmpdir: str = "/tmp/repro_storage_bench", n_trials: int = 200, verbose: bool = True):
    import os
    import shutil

    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    rows = {}
    backends = {
        "inmemory": hpo.InMemoryStorage(),
        "sqlite": hpo.SQLiteStorage(f"{tmpdir}/b.db"),
        "journal": hpo.JournalStorage(f"{tmpdir}/b.journal"),
    }
    for name, st in backends.items():
        rows[name] = _bench(st, n_trials)
        if verbose:
            r = rows[name]
            print(
                f"[storage] {name:9s} create={r['create_per_sec']:9.0f}/s "
                f"write={r['write_per_sec']:9.0f}/s read={r['full_read_per_sec']:7.1f}/s",
                flush=True,
            )
    return rows
