"""Storage backend throughput (beyond-paper; Table-2 'lightweight' claim made
quantitative): ops/sec per backend for the three dominant operations, plus a
remote-vs-sqlite-vs-cached comparison of the ``get_all_trials``-dominated
``ask`` path (the per-suggest full-history read every sampler performs), plus
the 100+-concurrent-worker multi-objective storm pinning the ``tell_batch``
vector-values frame cost on the ``StorageServer`` (ROADMAP PR-1 follow-up)."""

from __future__ import annotations

import threading
import time

import repro.core as hpo
from repro.core import telemetry
from repro.core.distributions import FloatDistribution
from repro.core.frozen import StudyDirection, TrialState

__all__ = [
    "run",
    "ask_latency",
    "moo_worker_storm",
    "sharded_worker_storm",
    "telemetry_overhead",
    "main",
]


def _percentiles(xs: "list[float]") -> dict:
    """Nearest-rank p50/p95/p99 over a non-empty sample list."""
    s = sorted(xs)

    def q(p: float) -> float:
        return s[min(len(s) - 1, int(round(p * (len(s) - 1))))]

    return {"p50": q(0.50), "p95": q(0.95), "p99": q(0.99)}


def _bench(storage, n_trials: int = 200, study_name: str = "bench"):
    sid = storage.create_new_study([StudyDirection.MINIMIZE], study_name)
    t0 = time.time()
    tids = [storage.create_new_trial(sid) for _ in range(n_trials)]
    t_create = time.time() - t0

    t0 = time.time()
    for tid in tids:
        storage.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
        storage.set_trial_intermediate_value(tid, 1, 1.0)
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [1.0])
    t_write = time.time() - t0

    t0 = time.time()
    for _ in range(20):
        trials = storage.get_all_trials(sid, deepcopy=False)
    t_read = time.time() - t0
    assert len(trials) == n_trials
    return {
        "create_per_sec": n_trials / max(t_create, 1e-9),
        "write_per_sec": 3 * n_trials / max(t_write, 1e-9),
        "full_read_per_sec": 20 / max(t_read, 1e-9),
    }


def ask_latency(n_trials: int = 1000, n_asks: int = 50, tmpdir: str = "/tmp/repro_ask_bench",
                verbose: bool = True):
    """Time the read that dominates ``ask`` — one ``get_all_trials`` per
    suggest — at ``n_trials`` completed trials, for the uncached remote path
    vs the :class:`CachedStorage` proxy over the same server.

    Returns per-ask latencies and the cached-path speedup (acceptance target:
    >= 2x at 1000 trials).
    """
    import os
    import shutil

    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)

    backend = hpo.SQLiteStorage(f"{tmpdir}/ask.db")
    with hpo.StorageServer(backend) as server:
        seed = hpo.RemoteStorage(server.url)
        sid = seed.create_new_study([StudyDirection.MINIMIZE], "ask-bench")
        for i in range(n_trials):
            tid = seed.create_new_trial(sid)
            seed.set_trial_param(tid, "x", (i % 97) / 97.0, FloatDistribution(0, 1))
            seed.set_trial_state_values(tid, TrialState.COMPLETE, [float(i % 13)])

        def time_asks(storage) -> "list[float]":
            storage.get_all_trials(sid, deepcopy=False)  # warm up / fill cache
            per_ask = []
            for _ in range(n_asks):
                t0 = time.perf_counter()
                trials = storage.get_all_trials(sid, deepcopy=False)
                per_ask.append(time.perf_counter() - t0)
            assert len(trials) == n_trials
            return per_ask

        remote_ts = time_asks(hpo.RemoteStorage(server.url))
        cached_ts = time_asks(hpo.CachedStorage(hpo.RemoteStorage(server.url)))

    remote_s = sum(remote_ts) / len(remote_ts)
    cached_s = sum(cached_ts) / len(cached_ts)
    speedup = remote_s / max(cached_s, 1e-9)
    row = {
        "n_trials": n_trials,
        "remote_ask_ms": remote_s * 1e3,
        "cached_ask_ms": cached_s * 1e3,
        "cached_speedup": speedup,
    }
    row.update({f"remote_ask_{k}_ms": v * 1e3 for k, v in _percentiles(remote_ts).items()})
    row.update({f"cached_ask_{k}_ms": v * 1e3 for k, v in _percentiles(cached_ts).items()})
    if verbose:
        print(
            f"[ask@{n_trials}] remote={row['remote_ask_ms']:8.2f}ms "
            f"cached={row['cached_ask_ms']:8.3f}ms speedup={speedup:6.1f}x",
            flush=True,
        )
    return row


def moo_worker_storm(
    n_workers: int = 100,
    waves_per_worker: int = 3,
    wave: int = 4,
    n_objectives: int = 3,
    protocol: int = 2,
    verbose: bool = True,
) -> dict:
    """100+ concurrent workers hammering one :class:`StorageServer` with the
    batched multi-objective lifecycle: each worker loops ``ask(wave)`` →
    ``tell_batch`` with **vector** final values, every worker on its own
    connection (the server multiplexes them all on one reactor thread,
    matching a real fleet).

    Measures aggregate trial throughput and the mean ``tell_batch`` frame
    latency — the cost of shipping ``wave`` state transitions each carrying
    an ``n_objectives``-wide values vector in one frame.  ``protocol`` pins
    the wire format: 1 forces legacy JSON frames (the pre-v2 baseline), 2
    negotiates the binary columnar encoding.
    """
    server = hpo.StorageServer(hpo.InMemoryStorage(), max_protocol=protocol).start()
    try:
        seed = hpo.RemoteStorage(server.url)
        seed.create_new_study([StudyDirection.MINIMIZE] * n_objectives, "storm")
        tell_ns: list[int] = []
        tell_lock = threading.Lock()
        errors: list[BaseException] = []
        start_barrier = threading.Barrier(n_workers)

        def worker(widx: int) -> None:
            try:
                study = hpo.Study("storm", hpo.RemoteStorage(server.url))
                start_barrier.wait(timeout=60)
                for _ in range(waves_per_worker):
                    trials = study.ask(wave)
                    results = [
                        (t, [float((widx + j) % 7)] * n_objectives)
                        for j, t in enumerate(trials)
                    ]
                    t0 = time.perf_counter_ns()
                    study.tell_batch(results)
                    dt = time.perf_counter_ns() - t0
                    with tell_lock:
                        tell_ns.append(dt)
            except BaseException as e:  # pragma: no cover - surfaced below
                errors.append(e)
                # a worker dying before its barrier wait would strand the
                # other n-1 parties forever: break the barrier so they fail
                # fast (BrokenBarrierError) instead of hanging the bench job
                start_barrier.abort()

        threads = [
            threading.Thread(target=worker, args=(i,), daemon=True)
            for i in range(n_workers)
        ]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]
        n_total = n_workers * waves_per_worker * wave
        done = seed.get_n_trials(
            seed.get_study_id_from_name("storm"), states=(TrialState.COMPLETE,)
        )
        assert done == n_total, (done, n_total)
        tell_ms = sorted(ns / 1e6 for ns in tell_ns)
        pcts = _percentiles(tell_ms)
        row = {
            "n_workers": n_workers,
            "n_objectives": n_objectives,
            "protocol": protocol,
            "wave": wave,
            "trials_total": n_total,
            "wall_s": wall,
            "trials_per_sec": n_total / max(wall, 1e-9),
            "tell_batch_mean_ms": sum(tell_ms) / len(tell_ms),
            "tell_batch_p50_ms": pcts["p50"],
            "tell_batch_p95_ms": pcts["p95"],
            "tell_batch_p99_ms": pcts["p99"],
            # server-side view of the same storm: per-RPC counts, latency
            # percentiles and bytes shipped, straight from the metrics RPC
            "server_metrics": server.get_server_metrics(),
        }
        if verbose:
            print(
                f"[storm] {n_workers} workers x {n_objectives} objectives "
                f"(wire v{protocol}): "
                f"{row['trials_per_sec']:8.0f} trials/s, tell_batch "
                f"mean={row['tell_batch_mean_ms']:6.2f}ms "
                f"p95={row['tell_batch_p95_ms']:6.2f}ms",
                flush=True,
            )
        return row
    finally:
        server.stop()


class _ModeledCommitBackend:
    """:class:`InMemoryStorage` plus a fixed per-write commit latency.

    The sharded-storm row wants to pin an *architectural* property: a single
    reactor serializes the whole fleet behind its backend's commit latency,
    while shards overlap their commits.  On real hardware the latency comes
    from the durable device (NVMe fsync ~100us, EBS ~0.5-1ms); in a
    single-core container with one ext4 journal, genuinely parallel commits
    are physically unavailable (jbd2 serializes fsyncs across files), so the
    commit is *modeled* as a ``time.sleep`` — the kernel overlaps sleeps the
    way independent disks overlap syncs.  The modeled value is recorded in
    the bench row (``modeled_commit_ms``); both rows use the identical
    backend, so the ratio is a fair read of reactor serialization.
    """

    def __new__(cls, commit_s: float):
        import time as _time

        class _Backend(hpo.InMemoryStorage):
            def _commit(self):
                _time.sleep(commit_s)

            def create_new_study(self, *a, **k):
                self._commit()
                return super().create_new_study(*a, **k)

            def create_new_trial(self, *a, **k):
                self._commit()
                return super().create_new_trial(*a, **k)

            def set_trial_param(self, *a, **k):
                self._commit()
                return super().set_trial_param(*a, **k)

            def set_trial_intermediate_value(self, *a, **k):
                self._commit()
                return super().set_trial_intermediate_value(*a, **k)

            def set_trial_state_values(self, *a, **k):
                self._commit()
                return super().set_trial_state_values(*a, **k)

        return _Backend()


def _sharded_storm_server_main(q, stop_evt, commit_s) -> None:
    """Subprocess entry: serve one shard until told to stop.  Each shard gets
    its own *process* (not thread) so shard reactors genuinely run
    side-by-side rather than time-slicing one GIL."""
    server = hpo.StorageServer(_ModeledCommitBackend(commit_s)).start()
    q.put(server.url)
    stop_evt.wait()
    server.stop()


def _sharded_storm_worker_main(urls, study_name, n_trials, widx) -> None:
    """Subprocess entry: one worker's trial loop against the pool — create,
    then one batched frame carrying the param / curve-point / final-state
    writes (the fleet wire-amortization pattern)."""
    from repro.core.storage import RemoteStorage, ShardedStorage

    storage = ShardedStorage(list(urls)) if len(urls) > 1 else RemoteStorage(urls[0])
    sid = storage.get_study_id_from_name(study_name)
    dist = FloatDistribution(0, 1)
    for k in range(n_trials):
        tid = storage.create_new_trial(sid)
        storage.call_batch(
            [
                ("set_trial_param", (tid, "x", (widx + k) % 97 / 97.0, dist)),
                ("set_trial_intermediate_value", (tid, 0, float(k))),
                (
                    "set_trial_state_values",
                    (tid, TrialState.COMPLETE, [float((widx + k) % 7)]),
                ),
            ]
        )
    storage.close()


def sharded_worker_storm(
    n_shards: int = 3,
    n_workers: int = 16,
    trials_per_worker: int = 15,
    commit_ms: float = 1.0,
    verbose: bool = True,
) -> dict:
    """The cluster-scaling row: the worker storm run twice at *equal* total
    workers — once against a single server, once against ``n_shards`` servers
    behind :class:`ShardedStorage` — with every server and every worker in
    its own process.  Both pools serve the same commit-latency backend (see
    :class:`_ModeledCommitBackend`), so the single-server row is honestly
    bottlenecked on one reactor draining one commit queue.

    Studies (``2 * n_shards`` of them, names chosen so the consistent-hash
    ring places an equal number on every shard) are spread round-robin over
    the workers; each study lives wholly on one shard, so the router adds no
    cross-shard chatter — the speedup measures commit overlap across shard
    reactors, which is exactly what sharding buys (acceptance target:
    >= 1.5x aggregate trials/s at 3 shards).
    """
    import multiprocessing as mp

    from repro.core.storage import RemoteStorage, ShardedStorage
    from repro.core.storage.cluster import HashRing

    ctx = mp.get_context("fork")
    n_studies = 2 * n_shards
    # pick study names the ring spreads evenly: walk storm-0, storm-1, ...
    # keeping a name only while its shard is under quota
    ring, names, fill = HashRing(n_shards), [], [0] * n_shards
    i = 0
    while len(names) < n_studies:
        nm = f"storm-{i}"
        i += 1
        s = ring.lookup(nm)
        if fill[s] < n_studies // n_shards:
            names.append(nm)
            fill[s] += 1

    def launch_pool(n):
        q, stop = ctx.Queue(), ctx.Event()
        procs = [
            ctx.Process(
                target=_sharded_storm_server_main,
                args=(q, stop, commit_ms / 1e3),
                daemon=True,
            )
            for _ in range(n)
        ]
        for p in procs:
            p.start()
        urls = [q.get(timeout=30) for _ in procs]
        return procs, stop, urls

    def run_fleet(urls) -> float:
        admin = ShardedStorage(list(urls)) if len(urls) > 1 else RemoteStorage(urls[0])
        for nm in names:
            admin.create_new_study([StudyDirection.MINIMIZE], nm)
        workers = [
            ctx.Process(
                target=_sharded_storm_worker_main,
                args=(urls, names[w % n_studies], trials_per_worker, w),
                daemon=True,
            )
            for w in range(n_workers)
        ]
        t0 = time.perf_counter()
        for p in workers:
            p.start()
        for p in workers:
            p.join()
        wall = time.perf_counter() - t0
        assert all(p.exitcode == 0 for p in workers), [p.exitcode for p in workers]
        total = sum(
            admin.get_n_trials(
                admin.get_study_id_from_name(nm), states=(TrialState.COMPLETE,)
            )
            for nm in names
        )
        expected = n_workers * trials_per_worker
        assert total == expected, (total, expected)
        admin.close()
        return wall

    procs, stop, urls = launch_pool(1)
    try:
        wall_single = run_fleet(urls)
    finally:
        stop.set()
        for p in procs:
            p.join(timeout=10)
    procs, stop, urls = launch_pool(n_shards)
    try:
        wall_sharded = run_fleet(urls)
    finally:
        stop.set()
        for p in procs:
            p.join(timeout=10)

    n_total = n_workers * trials_per_worker
    single_tps = n_total / max(wall_single, 1e-9)
    sharded_tps = n_total / max(wall_sharded, 1e-9)
    row = {
        "n_shards": n_shards,
        "n_workers": n_workers,
        "n_studies": n_studies,
        "trials_total": n_total,
        "modeled_commit_ms": commit_ms,
        "single_wall_s": wall_single,
        "sharded_wall_s": wall_sharded,
        "single_trials_per_sec": single_tps,
        "sharded_trials_per_sec": sharded_tps,
        "speedup_vs_single": sharded_tps / max(single_tps, 1e-9),
    }
    if verbose:
        print(
            f"[sharded-storm] {n_workers} worker procs, {n_shards} shards: "
            f"single={single_tps:7.0f} trials/s "
            f"sharded={sharded_tps:7.0f} trials/s "
            f"speedup={row['speedup_vs_single']:4.2f}x",
            flush=True,
        )
    return row


def run(tmpdir: str = "/tmp/repro_storage_bench", n_trials: int = 200, verbose: bool = True,
        storm_workers: int = 100):
    import os
    import shutil

    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    rows = {}

    server = hpo.StorageServer(hpo.SQLiteStorage(f"{tmpdir}/served.db")).start()
    try:
        backends = {
            "inmemory": hpo.InMemoryStorage(),
            "sqlite": hpo.SQLiteStorage(f"{tmpdir}/b.db"),
            "journal": hpo.JournalStorage(f"{tmpdir}/b.journal"),
            "remote": hpo.RemoteStorage(server.url),
            "remote+cache": hpo.CachedStorage(hpo.RemoteStorage(server.url)),
        }
        for name, st in backends.items():
            # remote backends share one server -> unique study names
            rows[name] = _bench(st, n_trials, study_name=f"bench-{name}")
            if verbose:
                r = rows[name]
                print(
                    f"[storage] {name:12s} create={r['create_per_sec']:9.0f}/s "
                    f"write={r['write_per_sec']:9.0f}/s read={r['full_read_per_sec']:7.1f}/s",
                    flush=True,
                )
    finally:
        server.stop()

    rows["ask_latency"] = ask_latency(verbose=verbose)
    # v1-vs-v2 storm at the same worker count: the legacy-JSON baseline row
    # next to the binary wire row pins the protocol's contribution
    rows["moo_worker_storm_v1"] = moo_worker_storm(
        n_workers=storm_workers, protocol=1, verbose=verbose
    )
    rows["moo_worker_storm"] = moo_worker_storm(
        n_workers=storm_workers, protocol=2, verbose=verbose
    )
    return rows


def telemetry_overhead(n_trials: int = 300, reps: int = 5, verbose: bool = True) -> dict:
    """Pin the cost of the telemetry backbone on the hot path.

    Runs the same in-memory ask/report/prune workload with the global
    registry disabled (the production default) and enabled, and micro-times
    a bare ``span()`` in both modes.  Acceptance: disabled overhead < 2%,
    enabled < 5% of end-to-end optimize wall time.
    """
    def timed_run() -> float:
        study = hpo.create_study(
            sampler=hpo.RandomSampler(seed=0), pruner=hpo.MedianPruner(n_warmup_steps=0)
        )

        def obj(trial):
            x = trial.suggest_float("x", 0, 1)
            for step in range(3):
                trial.report(x + 0.1 * step, step)
                if trial.should_prune():
                    raise hpo.TrialPruned()
            return x

        t0 = time.perf_counter()
        study.optimize(obj, n_trials=n_trials)
        return time.perf_counter() - t0

    def span_ns(n: int = 100_000) -> float:
        t0 = time.perf_counter_ns()
        for _ in range(n):
            with telemetry.span("bench.noop"):
                pass
        return (time.perf_counter_ns() - t0) / n

    was_enabled = telemetry.enabled()
    telemetry.disable()
    try:
        timed_run()  # warm caches / JIT-free but import-heavy first run
        disabled_s = min(timed_run() for _ in range(reps))
        disabled_span_ns = span_ns()
        telemetry.enable()
        enabled_s = min(timed_run() for _ in range(reps))
        enabled_span_ns = span_ns()
    finally:
        telemetry.disable()
        telemetry.reset()
        if was_enabled:
            telemetry.enable()
    overhead_pct = (enabled_s - disabled_s) / max(disabled_s, 1e-9) * 100.0
    row = {
        "n_trials": n_trials,
        "disabled_s": disabled_s,
        "enabled_s": enabled_s,
        "enabled_overhead_pct": overhead_pct,
        "disabled_span_ns": disabled_span_ns,
        "enabled_span_ns": enabled_span_ns,
    }
    if verbose:
        print(
            f"[telemetry] optimize({n_trials}) disabled={disabled_s*1e3:7.1f}ms "
            f"enabled={enabled_s*1e3:7.1f}ms overhead={overhead_pct:+5.1f}% "
            f"span={disabled_span_ns:.0f}ns off / {enabled_span_ns:.0f}ns on",
            flush=True,
        )
    return row


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description="storage backend benchmarks")
    ap.add_argument("--out", default="BENCH_storage.json")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="also dump the client-side telemetry.snapshot() "
                         "accumulated across the benchmark run")
    ap.add_argument("--trials", type=int, default=200,
                    help="trials per backend in the ops/sec comparison")
    ap.add_argument("--workers", type=int, default=100,
                    help="concurrent workers in the multi-objective storm")
    ap.add_argument("--storm-1k", action="store_true",
                    help="also run the 1000-concurrent-worker storm row "
                         "(slow; CI passes this, optional locally)")
    ap.add_argument("--storm-sharded", action="store_true",
                    help="also run the cluster-scaling row: the storm at "
                         "equal workers against 1 vs N sharded server "
                         "processes (CI passes this)")
    ap.add_argument("--shards", type=int, default=3,
                    help="server pool size for --storm-sharded (2-3 typical)")
    args = ap.parse_args(argv)

    try:
        from ._meta import bench_metadata
    except ImportError:  # run as a standalone script, not -m benchmarks.storage_bench
        from _meta import bench_metadata

    # overhead row first: it needs exclusive control of the global registry
    payload: dict = {"telemetry_overhead": telemetry_overhead()}

    # the rest runs with telemetry on so --metrics-json captures the
    # client-side view (per-RPC latency histograms, frame/byte counters)
    telemetry.enable()
    try:
        rows = run(n_trials=args.trials, verbose=True, storm_workers=args.workers)
        payload.update(rows)
        if args.storm_1k:
            payload["moo_worker_storm_1k"] = moo_worker_storm(
                n_workers=1000, protocol=2, verbose=True
            )
        if args.storm_sharded:
            payload["sharded_worker_storm"] = sharded_worker_storm(
                n_shards=args.shards, verbose=True
            )
        snapshot = telemetry.snapshot()
    finally:
        telemetry.disable()
        telemetry.reset()
    payload["meta"] = bench_metadata()

    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[storage] wrote {args.out}", flush=True)
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(snapshot, f, indent=2, sort_keys=True)
        print(f"[storage] wrote {args.metrics_json}", flush=True)


if __name__ == "__main__":
    main()
