"""Storage backend throughput (beyond-paper; Table-2 'lightweight' claim made
quantitative): ops/sec per backend for the three dominant operations, plus a
remote-vs-sqlite-vs-cached comparison of the ``get_all_trials``-dominated
``ask`` path (the per-suggest full-history read every sampler performs), plus
the 100+-concurrent-worker multi-objective storm pinning the ``tell_batch``
vector-values frame cost on the ``StorageServer`` (ROADMAP PR-1 follow-up)."""

from __future__ import annotations

import threading
import time

import repro.core as hpo
from repro.core.distributions import FloatDistribution
from repro.core.frozen import StudyDirection, TrialState

__all__ = ["run", "ask_latency", "moo_worker_storm"]


def _bench(storage, n_trials: int = 200, study_name: str = "bench"):
    sid = storage.create_new_study([StudyDirection.MINIMIZE], study_name)
    t0 = time.time()
    tids = [storage.create_new_trial(sid) for _ in range(n_trials)]
    t_create = time.time() - t0

    t0 = time.time()
    for tid in tids:
        storage.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
        storage.set_trial_intermediate_value(tid, 1, 1.0)
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [1.0])
    t_write = time.time() - t0

    t0 = time.time()
    for _ in range(20):
        trials = storage.get_all_trials(sid, deepcopy=False)
    t_read = time.time() - t0
    assert len(trials) == n_trials
    return {
        "create_per_sec": n_trials / max(t_create, 1e-9),
        "write_per_sec": 3 * n_trials / max(t_write, 1e-9),
        "full_read_per_sec": 20 / max(t_read, 1e-9),
    }


def ask_latency(n_trials: int = 1000, n_asks: int = 50, tmpdir: str = "/tmp/repro_ask_bench",
                verbose: bool = True):
    """Time the read that dominates ``ask`` — one ``get_all_trials`` per
    suggest — at ``n_trials`` completed trials, for the uncached remote path
    vs the :class:`CachedStorage` proxy over the same server.

    Returns per-ask latencies and the cached-path speedup (acceptance target:
    >= 2x at 1000 trials).
    """
    import os
    import shutil

    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)

    backend = hpo.SQLiteStorage(f"{tmpdir}/ask.db")
    with hpo.StorageServer(backend) as server:
        seed = hpo.RemoteStorage(server.url)
        sid = seed.create_new_study([StudyDirection.MINIMIZE], "ask-bench")
        for i in range(n_trials):
            tid = seed.create_new_trial(sid)
            seed.set_trial_param(tid, "x", (i % 97) / 97.0, FloatDistribution(0, 1))
            seed.set_trial_state_values(tid, TrialState.COMPLETE, [float(i % 13)])

        def time_asks(storage) -> float:
            storage.get_all_trials(sid, deepcopy=False)  # warm up / fill cache
            t0 = time.time()
            for _ in range(n_asks):
                trials = storage.get_all_trials(sid, deepcopy=False)
            assert len(trials) == n_trials
            return (time.time() - t0) / n_asks

        remote_s = time_asks(hpo.RemoteStorage(server.url))
        cached_s = time_asks(hpo.CachedStorage(hpo.RemoteStorage(server.url)))

    speedup = remote_s / max(cached_s, 1e-9)
    row = {
        "n_trials": n_trials,
        "remote_ask_ms": remote_s * 1e3,
        "cached_ask_ms": cached_s * 1e3,
        "cached_speedup": speedup,
    }
    if verbose:
        print(
            f"[ask@{n_trials}] remote={row['remote_ask_ms']:8.2f}ms "
            f"cached={row['cached_ask_ms']:8.3f}ms speedup={speedup:6.1f}x",
            flush=True,
        )
    return row


def moo_worker_storm(
    n_workers: int = 100,
    waves_per_worker: int = 3,
    wave: int = 4,
    n_objectives: int = 3,
    verbose: bool = True,
) -> dict:
    """100+ concurrent workers hammering one :class:`StorageServer` with the
    batched multi-objective lifecycle: each worker loops ``ask(wave)`` →
    ``tell_batch`` with **vector** final values, every worker on its own
    connection (thread-per-connection on the server, matching a real fleet).

    Measures aggregate trial throughput and the mean ``tell_batch`` frame
    latency — the cost of shipping ``wave`` state transitions each carrying
    an ``n_objectives``-wide values vector in one frame — to pin whether the
    vector payload moves the server off its single-objective numbers.
    """
    server = hpo.StorageServer(hpo.InMemoryStorage()).start()
    try:
        seed = hpo.RemoteStorage(server.url)
        seed.create_new_study([StudyDirection.MINIMIZE] * n_objectives, "storm")
        tell_ns: list[int] = []
        tell_lock = threading.Lock()
        errors: list[BaseException] = []
        start_barrier = threading.Barrier(n_workers)

        def worker(widx: int) -> None:
            try:
                study = hpo.Study("storm", hpo.RemoteStorage(server.url))
                start_barrier.wait(timeout=60)
                for _ in range(waves_per_worker):
                    trials = study.ask(wave)
                    results = [
                        (t, [float((widx + j) % 7)] * n_objectives)
                        for j, t in enumerate(trials)
                    ]
                    t0 = time.perf_counter_ns()
                    study.tell_batch(results)
                    dt = time.perf_counter_ns() - t0
                    with tell_lock:
                        tell_ns.append(dt)
            except BaseException as e:  # pragma: no cover - surfaced below
                errors.append(e)
                # a worker dying before its barrier wait would strand the
                # other n-1 parties forever: break the barrier so they fail
                # fast (BrokenBarrierError) instead of hanging the bench job
                start_barrier.abort()

        threads = [
            threading.Thread(target=worker, args=(i,), daemon=True)
            for i in range(n_workers)
        ]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]
        n_total = n_workers * waves_per_worker * wave
        done = seed.get_n_trials(
            seed.get_study_id_from_name("storm"), states=(TrialState.COMPLETE,)
        )
        assert done == n_total, (done, n_total)
        tell_ms = sorted(ns / 1e6 for ns in tell_ns)
        row = {
            "n_workers": n_workers,
            "n_objectives": n_objectives,
            "wave": wave,
            "trials_total": n_total,
            "wall_s": wall,
            "trials_per_sec": n_total / max(wall, 1e-9),
            "tell_batch_mean_ms": sum(tell_ms) / len(tell_ms),
            "tell_batch_p95_ms": tell_ms[int(0.95 * (len(tell_ms) - 1))],
        }
        if verbose:
            print(
                f"[storm] {n_workers} workers x {n_objectives} objectives: "
                f"{row['trials_per_sec']:8.0f} trials/s, tell_batch "
                f"mean={row['tell_batch_mean_ms']:6.2f}ms "
                f"p95={row['tell_batch_p95_ms']:6.2f}ms",
                flush=True,
            )
        return row
    finally:
        server.stop()


def run(tmpdir: str = "/tmp/repro_storage_bench", n_trials: int = 200, verbose: bool = True):
    import os
    import shutil

    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    rows = {}

    server = hpo.StorageServer(hpo.SQLiteStorage(f"{tmpdir}/served.db")).start()
    try:
        backends = {
            "inmemory": hpo.InMemoryStorage(),
            "sqlite": hpo.SQLiteStorage(f"{tmpdir}/b.db"),
            "journal": hpo.JournalStorage(f"{tmpdir}/b.journal"),
            "remote": hpo.RemoteStorage(server.url),
            "remote+cache": hpo.CachedStorage(hpo.RemoteStorage(server.url)),
        }
        for name, st in backends.items():
            # remote backends share one server -> unique study names
            rows[name] = _bench(st, n_trials, study_name=f"bench-{name}")
            if verbose:
                r = rows[name]
                print(
                    f"[storage] {name:12s} create={r['create_per_sec']:9.0f}/s "
                    f"write={r['write_per_sec']:9.0f}/s read={r['full_read_per_sec']:7.1f}/s",
                    flush=True,
                )
    finally:
        server.stop()

    rows["ask_latency"] = ask_latency(verbose=verbose)
    rows["moo_worker_storm"] = moo_worker_storm(verbose=verbose)
    return rows
