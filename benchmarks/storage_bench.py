"""Storage backend throughput (beyond-paper; Table-2 'lightweight' claim made
quantitative): ops/sec per backend for the three dominant operations, plus a
remote-vs-sqlite-vs-cached comparison of the ``get_all_trials``-dominated
``ask`` path (the per-suggest full-history read every sampler performs)."""

from __future__ import annotations

import time

import repro.core as hpo
from repro.core.distributions import FloatDistribution
from repro.core.frozen import StudyDirection, TrialState

__all__ = ["run", "ask_latency"]


def _bench(storage, n_trials: int = 200, study_name: str = "bench"):
    sid = storage.create_new_study([StudyDirection.MINIMIZE], study_name)
    t0 = time.time()
    tids = [storage.create_new_trial(sid) for _ in range(n_trials)]
    t_create = time.time() - t0

    t0 = time.time()
    for tid in tids:
        storage.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
        storage.set_trial_intermediate_value(tid, 1, 1.0)
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [1.0])
    t_write = time.time() - t0

    t0 = time.time()
    for _ in range(20):
        trials = storage.get_all_trials(sid, deepcopy=False)
    t_read = time.time() - t0
    assert len(trials) == n_trials
    return {
        "create_per_sec": n_trials / max(t_create, 1e-9),
        "write_per_sec": 3 * n_trials / max(t_write, 1e-9),
        "full_read_per_sec": 20 / max(t_read, 1e-9),
    }


def ask_latency(n_trials: int = 1000, n_asks: int = 50, tmpdir: str = "/tmp/repro_ask_bench",
                verbose: bool = True):
    """Time the read that dominates ``ask`` — one ``get_all_trials`` per
    suggest — at ``n_trials`` completed trials, for the uncached remote path
    vs the :class:`CachedStorage` proxy over the same server.

    Returns per-ask latencies and the cached-path speedup (acceptance target:
    >= 2x at 1000 trials).
    """
    import os
    import shutil

    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)

    backend = hpo.SQLiteStorage(f"{tmpdir}/ask.db")
    with hpo.StorageServer(backend) as server:
        seed = hpo.RemoteStorage(server.url)
        sid = seed.create_new_study([StudyDirection.MINIMIZE], "ask-bench")
        for i in range(n_trials):
            tid = seed.create_new_trial(sid)
            seed.set_trial_param(tid, "x", (i % 97) / 97.0, FloatDistribution(0, 1))
            seed.set_trial_state_values(tid, TrialState.COMPLETE, [float(i % 13)])

        def time_asks(storage) -> float:
            storage.get_all_trials(sid, deepcopy=False)  # warm up / fill cache
            t0 = time.time()
            for _ in range(n_asks):
                trials = storage.get_all_trials(sid, deepcopy=False)
            assert len(trials) == n_trials
            return (time.time() - t0) / n_asks

        remote_s = time_asks(hpo.RemoteStorage(server.url))
        cached_s = time_asks(hpo.CachedStorage(hpo.RemoteStorage(server.url)))

    speedup = remote_s / max(cached_s, 1e-9)
    row = {
        "n_trials": n_trials,
        "remote_ask_ms": remote_s * 1e3,
        "cached_ask_ms": cached_s * 1e3,
        "cached_speedup": speedup,
    }
    if verbose:
        print(
            f"[ask@{n_trials}] remote={row['remote_ask_ms']:8.2f}ms "
            f"cached={row['cached_ask_ms']:8.3f}ms speedup={speedup:6.1f}x",
            flush=True,
        )
    return row


def run(tmpdir: str = "/tmp/repro_storage_bench", n_trials: int = 200, verbose: bool = True):
    import os
    import shutil

    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    rows = {}

    server = hpo.StorageServer(hpo.SQLiteStorage(f"{tmpdir}/served.db")).start()
    try:
        backends = {
            "inmemory": hpo.InMemoryStorage(),
            "sqlite": hpo.SQLiteStorage(f"{tmpdir}/b.db"),
            "journal": hpo.JournalStorage(f"{tmpdir}/b.journal"),
            "remote": hpo.RemoteStorage(server.url),
            "remote+cache": hpo.CachedStorage(hpo.RemoteStorage(server.url)),
        }
        for name, st in backends.items():
            # remote backends share one server -> unique study names
            rows[name] = _bench(st, n_trials, study_name=f"bench-{name}")
            if verbose:
                r = rows[name]
                print(
                    f"[storage] {name:12s} create={r['create_per_sec']:9.0f}/s "
                    f"write={r['write_per_sec']:9.0f}/s read={r['full_read_per_sec']:7.1f}/s",
                    flush=True,
                )
    finally:
        server.stop()

    rows["ask_latency"] = ask_latency(verbose=verbose)
    return rows
