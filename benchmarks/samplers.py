"""Paper §5.1 (Fig. 9 + Fig. 10): sampler comparison on the 56-case black-box
suite with paired Mann-Whitney U tests, plus per-trial wall time.

Default budget is scaled for CPU CI (full paper scale: repeats=30, trials=80,
all 56 cases — pass --full).
"""

from __future__ import annotations

import math
import time

import numpy as np

import repro.core as hpo
from .testbed import CASES

__all__ = ["run", "mann_whitney_u"]


def mann_whitney_u(a, b) -> float:
    """One-sided p-value that distribution a < b (normal approximation),
    matching the paper's paired Mann-Whitney testing protocol."""
    a, b = np.asarray(a, float), np.asarray(b, float)
    n1, n2 = len(a), len(b)
    all_v = np.concatenate([a, b])
    order = np.argsort(all_v, kind="stable")
    ranks = np.empty(len(all_v))
    # average ranks for ties
    sv = all_v[order]
    i = 0
    while i < len(sv):
        j = i
        while j + 1 < len(sv) and sv[j + 1] == sv[i]:
            j += 1
        ranks[order[i : j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    r1 = ranks[:n1].sum()
    u1 = r1 - n1 * (n1 + 1) / 2.0
    mu = n1 * n2 / 2.0
    sigma = math.sqrt(n1 * n2 * (n1 + n2 + 1) / 12.0) or 1.0
    z = (u1 - mu) / sigma
    return 0.5 * (1 + math.erf(z / math.sqrt(2)))  # P(a tends larger)


def _objective_for(case):
    def obj(trial):
        x = np.array(
            [trial.suggest_float(f"x{i}", lo, hi) for i, (lo, hi) in enumerate(case.bounds)]
        )
        return case.fn(x)

    return obj


def run(
    samplers=("random", "tpe", "tpe+cmaes", "gp"),
    n_cases: int = 12,
    n_trials: int = 40,
    repeats: int = 5,
    alpha: float = 0.0005,
    verbose: bool = True,
):
    """Returns rows: per (case, sampler): median best value + mean seconds per
    study, and the Fig. 9-style win/tie/loss table of tpe+cmaes vs rivals."""
    cases = CASES[:: max(1, len(CASES) // n_cases)][:n_cases]
    results: dict = {}
    times: dict = {}
    for case in cases:
        obj = _objective_for(case)
        for name in samplers:
            bests, elapsed = [], []
            for rep in range(repeats):
                sampler = hpo.make_sampler(name, seed=1000 + rep)
                study = hpo.create_study(sampler=sampler)
                t0 = time.time()
                study.optimize(obj, n_trials=n_trials)
                elapsed.append(time.time() - t0)
                bests.append(study.best_value)
            results[(case.name, name)] = bests
            times[(case.name, name)] = float(np.mean(elapsed))
            if verbose:
                print(
                    f"[samplers] {case.name:16s} {name:10s} "
                    f"median_best={np.median(bests):12.5g} regret={np.median(bests)-case.best:10.4g} "
                    f"sec/study={np.mean(elapsed):6.3f}",
                    flush=True,
                )

    # Fig. 9: TPE+CMA-ES vs each rival, paired Mann-Whitney per case
    summary = {}
    ours = "tpe+cmaes"
    for rival in samplers:
        if rival == ours:
            continue
        wins = losses = ties = 0
        for case in cases:
            a = results[(case.name, ours)]
            b = results[(case.name, rival)]
            p_better = mann_whitney_u(a, b)  # P(ours larger=worse)
            if p_better < alpha:
                wins += 1
            elif p_better > 1 - alpha:
                losses += 1
            else:
                ties += 1
        summary[rival] = {"wins": wins, "ties": ties, "losses": losses}
        if verbose:
            print(f"[samplers] tpe+cmaes vs {rival:8s}: {wins}W/{ties}T/{losses}L (alpha={alpha})")
    return {"results": results, "times": times, "summary": summary}
