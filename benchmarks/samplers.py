"""Paper §5.1 (Fig. 9 + Fig. 10): sampler comparison on the 56-case black-box
suite with paired Mann-Whitney U tests, plus per-trial wall time, plus the
**ask-throughput** benchmark for the columnar observation backbone (vectorized
TPE vs the frozen pre-refactor scalar path in ``samplers/_legacy.py``).

Default budget is scaled for CPU CI (full paper scale: repeats=30, trials=80,
all 56 cases — pass --full).  ``python -m benchmarks.samplers --ask-bench``
runs only the throughput comparison and writes ``BENCH_samplers.json`` (CI
uploads it as an artifact).
"""

from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

import repro.core as hpo
from repro.core.distributions import FloatDistribution
from repro.core.frozen import TrialState
from .testbed import CASES

__all__ = [
    "run", "mann_whitney_u", "ask_throughput", "engine_ask_bench",
    "joint_ask_throughput", "joint_quality", "main",
]


def mann_whitney_u(a, b) -> float:
    """One-sided p-value that distribution a < b (normal approximation),
    matching the paper's paired Mann-Whitney testing protocol."""
    a, b = np.asarray(a, float), np.asarray(b, float)
    n1, n2 = len(a), len(b)
    all_v = np.concatenate([a, b])
    order = np.argsort(all_v, kind="stable")
    ranks = np.empty(len(all_v))
    # average ranks for ties
    sv = all_v[order]
    i = 0
    while i < len(sv):
        j = i
        while j + 1 < len(sv) and sv[j + 1] == sv[i]:
            j += 1
        ranks[order[i : j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    r1 = ranks[:n1].sum()
    u1 = r1 - n1 * (n1 + 1) / 2.0
    mu = n1 * n2 / 2.0
    sigma = math.sqrt(n1 * n2 * (n1 + n2 + 1) / 12.0) or 1.0
    z = (u1 - mu) / sigma
    return 0.5 * (1 + math.erf(z / math.sqrt(2)))  # P(a tends larger)


def _objective_for(case):
    def obj(trial):
        x = np.array(
            [trial.suggest_float(f"x{i}", lo, hi) for i, (lo, hi) in enumerate(case.bounds)]
        )
        return case.fn(x)

    return obj


def run(
    samplers=("random", "tpe", "tpe+cmaes", "gp"),
    n_cases: int = 12,
    n_trials: int = 40,
    repeats: int = 5,
    alpha: float = 0.0005,
    verbose: bool = True,
):
    """Returns rows: per (case, sampler): median best value + mean seconds per
    study, and the Fig. 9-style win/tie/loss table of tpe+cmaes vs rivals."""
    cases = CASES[:: max(1, len(CASES) // n_cases)][:n_cases]
    results: dict = {}
    times: dict = {}
    for case in cases:
        obj = _objective_for(case)
        for name in samplers:
            bests, elapsed = [], []
            for rep in range(repeats):
                sampler = hpo.make_sampler(name, seed=1000 + rep)
                study = hpo.create_study(sampler=sampler)
                t0 = time.time()
                study.optimize(obj, n_trials=n_trials)
                elapsed.append(time.time() - t0)
                bests.append(study.best_value)
            results[(case.name, name)] = bests
            times[(case.name, name)] = float(np.mean(elapsed))
            if verbose:
                print(
                    f"[samplers] {case.name:16s} {name:10s} "
                    f"median_best={np.median(bests):12.5g} regret={np.median(bests)-case.best:10.4g} "
                    f"sec/study={np.mean(elapsed):6.3f}",
                    flush=True,
                )

    # Fig. 9: TPE+CMA-ES vs each rival, paired Mann-Whitney per case
    summary = {}
    ours = "tpe+cmaes"
    for rival in samplers:
        if rival == ours:
            continue
        wins = losses = ties = 0
        for case in cases:
            a = results[(case.name, ours)]
            b = results[(case.name, rival)]
            p_better = mann_whitney_u(a, b)  # P(ours larger=worse)
            if p_better < alpha:
                wins += 1
            elif p_better > 1 - alpha:
                losses += 1
            else:
                ties += 1
        summary[rival] = {"wins": wins, "ties": ties, "losses": losses}
        if verbose:
            print(f"[samplers] tpe+cmaes vs {rival:8s}: {wins}W/{ties}T/{losses}L (alpha={alpha})")
    return {"results": results, "times": times, "summary": summary}


# -- ask-throughput: columnar backbone vs pre-refactor scalar path ---------------


def _seed_history(study, n_trials: int, n_params: int, seed: int) -> None:
    """Populate a study with ``n_trials`` completed trials over ``n_params``
    mixed (linear/log) float parameters, writing straight to storage."""
    storage, sid = study._storage, study._study_id
    rng = np.random.RandomState(seed)
    dists = [
        FloatDistribution(-5, 5) if j % 2 == 0 else FloatDistribution(1e-6, 1.0, log=True)
        for j in range(n_params)
    ]
    for _ in range(n_trials):
        tid = storage.create_new_trial(sid)
        loss = 0.0
        for j, d in enumerate(dists):
            if d.log:
                v = float(np.exp(rng.uniform(np.log(1e-6), 0.0)))
                loss += abs(np.log10(v) + 3)
            else:
                v = float(rng.uniform(-5, 5))
                loss += v * v
            storage.set_trial_param(tid, f"p{j}", v, d)
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [loss])


def _ask_once(study, n_params: int) -> None:
    trial = study.ask()
    for j in range(n_params):
        if j % 2 == 0:
            trial.suggest_float(f"p{j}", -5, 5)
        else:
            trial.suggest_float(f"p{j}", 1e-6, 1.0, log=True)


def _bench_sampler(
    sampler, n_trials: int, n_params: int, n_asks: int, seed: int, warmup: int = 1
) -> float:
    """Median ms per ask (create trial + suggest every parameter) against a
    fixed completed history of ``n_trials``.  ``warmup`` asks run outside the
    clock (store ingest, fit caches, jit traces, and — for the device engine
    — the score table, which builds on the second score at one history
    version)."""
    study = hpo.create_study(sampler=sampler)
    _seed_history(study, n_trials, n_params, seed)
    for _ in range(max(warmup, 1)):
        _ask_once(study, n_params)
    times = []
    for _ in range(n_asks):
        t0 = time.perf_counter()
        _ask_once(study, n_params)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def ask_throughput(
    n_trials: int = 2000,
    n_params: int = 16,
    n_asks: int = 30,
    n_asks_legacy: int = 5,
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """TPE ask throughput: vectorized columnar path vs the frozen
    pre-refactor scalar path (``samplers/_legacy.py``), same seeded history.
    The acceptance bar for the backbone is >= 10x at 2000 trials x 16
    params."""
    from repro.core.samplers._legacy import LegacyTPESampler

    new_ms = _bench_sampler(hpo.TPESampler(seed=1), n_trials, n_params, n_asks, seed)
    legacy_ms = _bench_sampler(
        LegacyTPESampler(seed=1), n_trials, n_params, n_asks_legacy, seed
    )
    out = {
        "n_trials": n_trials,
        "n_params": n_params,
        "n_asks": n_asks,
        "vectorized_ms_per_ask": new_ms,
        "legacy_ms_per_ask": legacy_ms,
        "speedup": legacy_ms / max(new_ms, 1e-9),
    }
    if verbose:
        print(
            f"[samplers] TPE ask throughput @ {n_trials} trials x {n_params} params: "
            f"vectorized {new_ms:.2f} ms/ask, legacy {legacy_ms:.2f} ms/ask "
            f"-> {out['speedup']:.1f}x",
            flush=True,
        )
    return out


# -- engine scaling: numpy vs auto device engine ---------------------------------


def engine_ask_bench(
    sizes: tuple = (2000, 8000, 32000),
    n_params: int = 16,
    n_asks: int = 20,
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """TPE ask cost as the completed history grows: ``engine="numpy"`` vs
    the default ``engine="auto"`` device engine, same seeded histories.

    The numpy path rescores ``n_ei_candidates x n_components`` per parameter
    per ask, so its ask cost grows linearly with the history.  The auto
    engine amortizes repeat asks at one history version through the dense
    device score table (one large fused call, then O(n_ei) host interpolation
    per ask), so its ask cost stays flat.  Acceptance: auto grows <= 1.5x
    from the smallest to the largest size while numpy grows >= 5x."""
    rows = []
    for engine in ("numpy", "auto"):
        for n_trials in sizes:
            ms = _bench_sampler(
                hpo.TPESampler(seed=1, engine=engine),
                n_trials, n_params, n_asks, seed, warmup=3,
            )
            rows.append({"engine": engine, "n_trials": n_trials, "ms_per_ask": ms})
            if verbose:
                print(
                    f"[samplers] engine={engine:5s} @ {n_trials:6d} trials x "
                    f"{n_params} params: {ms:.2f} ms/ask",
                    flush=True,
                )

    def growth(engine: str) -> float:
        by_size = {r["n_trials"]: r["ms_per_ask"] for r in rows if r["engine"] == engine}
        return by_size[max(sizes)] / max(by_size[min(sizes)], 1e-9)

    out = {
        "n_params": n_params,
        "n_asks": n_asks,
        "sizes": list(sizes),
        "rows": rows,
        "numpy_growth": growth("numpy"),
        "auto_growth": growth("auto"),
    }
    if verbose:
        print(
            f"[samplers] ask-cost growth {min(sizes)} -> {max(sizes)} trials: "
            f"numpy {out['numpy_growth']:.1f}x, auto {out['auto_growth']:.1f}x",
            flush=True,
        )
    return out


# -- joint (multivariate) TPE: block sampling vs per-trial suggest ---------------


def joint_ask_throughput(
    n_trials: int = 2000,
    n_params: int = 16,
    batch: int = 16,
    n_waves: int = 5,
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """Joint ``ask(n)`` (one ``sample_joint`` block per group, multivariate
    TPE) vs per-trial univariate suggest, same seeded history and the same
    ``batch`` trials per wave.  Acceptance bar: >= 2x per-trial ask cost at
    2000 trials x 16 params."""

    def suggest_all(trial):
        for j in range(n_params):
            if j % 2 == 0:
                trial.suggest_float(f"p{j}", -5, 5)
            else:
                trial.suggest_float(f"p{j}", 1e-6, 1.0, log=True)

    def bench(multivariate: bool) -> float:
        study = hpo.create_study(
            sampler=hpo.TPESampler(seed=1, multivariate=multivariate)
        )
        _seed_history(study, n_trials, n_params, seed)
        wave = study.ask(batch)  # warm store + caches outside the clock
        for t in wave:
            suggest_all(t)
        times = []
        for _ in range(n_waves):
            t0 = time.perf_counter()
            wave = study.ask(batch)
            for t in wave:
                suggest_all(t)
            times.append(time.perf_counter() - t0)
        return float(np.median(times) * 1e3 / batch)

    joint_ms = bench(True)
    univariate_ms = bench(False)
    out = {
        "n_trials": n_trials,
        "n_params": n_params,
        "batch": batch,
        "joint_ms_per_trial": joint_ms,
        "univariate_ms_per_trial": univariate_ms,
        "speedup": univariate_ms / max(joint_ms, 1e-9),
    }
    if verbose:
        print(
            f"[samplers] joint ask throughput @ {n_trials} trials x {n_params} params "
            f"(waves of {batch}): joint {joint_ms:.2f} ms/trial, "
            f"univariate {univariate_ms:.2f} ms/trial -> {out['speedup']:.1f}x",
            flush=True,
        )
    return out


def joint_quality(
    n_trials: int = 200,
    batch: int = 16,
    seeds: tuple = (0, 1, 2),
    verbose: bool = True,
) -> dict:
    """Best value on a correlated 2-param objective (narrow valley along
    ``x = y``) at ``n_trials``: multivariate TPE models the correlation,
    univariate marginals cannot."""

    def objective(trial):
        x = trial.suggest_float("x", -5, 5)
        y = trial.suggest_float("y", -5, 5)
        return (x - y) ** 2 + 0.1 * (x + y - 2) ** 2

    def best(multivariate: bool, seed: int) -> float:
        study = hpo.create_study(
            sampler=hpo.TPESampler(seed=seed, n_startup_trials=10, multivariate=multivariate)
        )
        done = 0
        while done < n_trials:
            k = min(batch, n_trials - done)
            wave = study.ask(k)
            study.tell_batch([(t, objective(t)) for t in wave])
            done += k
        return float(study.best_value)

    rows = []
    wins = 0
    for seed in seeds:
        mv, uv = best(True, seed), best(False, seed)
        wins += mv < uv
        rows.append({"seed": seed, "multivariate_best": mv, "univariate_best": uv})
        if verbose:
            print(
                f"[samplers] correlated objective seed={seed}: "
                f"multivariate={mv:.5f} univariate={uv:.5f}",
                flush=True,
            )
    return {"objective": "(x-y)^2 + 0.1(x+y-2)^2", "n_trials": n_trials,
            "rows": rows, "multivariate_wins": wins, "n_seeds": len(seeds)}


def write_bench_json(payload: dict, path: str = "BENCH_samplers.json") -> None:
    try:
        from ._meta import bench_metadata
    except ImportError:  # run as a standalone script, not -m benchmarks.samplers
        from _meta import bench_metadata
    payload.setdefault("meta", bench_metadata())
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[samplers] wrote {path}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="sampler benchmarks")
    ap.add_argument("--ask-bench", action="store_true",
                    help="run the ask-throughput comparison (skips the full "
                         "sampler comparison unless other benches request it)")
    ap.add_argument("--joint-bench", action="store_true",
                    help="run the joint-vs-univariate block-sampling rows "
                         "(ask throughput in waves + correlated-objective quality)")
    ap.add_argument("--trials", type=int, default=2000)
    ap.add_argument("--params", type=int, default=16)
    ap.add_argument("--asks", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--full", action="store_true", help="paper-scale comparison budgets")
    ap.add_argument("--out", default="BENCH_samplers.json")
    args = ap.parse_args(argv)

    bench_only = args.ask_bench or args.joint_bench
    payload: dict = {}
    if args.ask_bench or not bench_only:
        payload["ask_throughput"] = ask_throughput(
            n_trials=args.trials, n_params=args.params, n_asks=args.asks
        )
        payload["engine_ask_bench"] = engine_ask_bench(n_params=args.params)
    if args.joint_bench or not bench_only:
        payload["joint_ask_throughput"] = joint_ask_throughput(
            n_trials=args.trials, n_params=args.params, batch=args.batch
        )
        payload["joint_quality"] = joint_quality()
    if not bench_only:
        budget = (
            dict(n_cases=56, n_trials=80, repeats=30) if args.full
            else dict(n_cases=8, n_trials=30, repeats=3)
        )
        out = run(**budget)
        payload["comparison"] = {
            "summary": out["summary"],
            "times": {f"{c}/{s}": v for (c, s), v in out["times"].items()},
        }
    write_bench_json(payload, args.out)


if __name__ == "__main__":
    main()
