"""Shared run metadata for every ``BENCH_*.json`` emitter.

A benchmark row without provenance is unreproducible noise: when CI uploads
the artifact, the consumer needs to know *which* commit, interpreter, and
numpy produced the numbers before comparing runs.  Each emitter attaches
``bench_metadata()`` under a ``"meta"`` key.
"""

from __future__ import annotations

import platform
import subprocess
import sys
import time

import numpy as np

__all__ = ["bench_metadata"]


def _git_rev() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=10,
        )
        if out.returncode == 0:
            rev = out.stdout.strip()
            dirty = subprocess.run(
                ["git", "status", "--porcelain"],
                capture_output=True, text=True, timeout=10,
            )
            if dirty.returncode == 0 and dirty.stdout.strip():
                rev += "-dirty"
            return rev
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def bench_metadata() -> dict:
    return {
        "git_rev": _git_rev(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "python": sys.version.split()[0],
        "numpy": np.__version__,
        "platform": platform.platform(),
    }
