"""Distributed optimization exactly as in paper Fig. 7: run this script N
times (or with --workers N to spawn locally) against a shared storage URL.

    # terminal 1..N (or different nodes over a shared filesystem):
    PYTHONPATH=src python examples/distributed_study.py --storage sqlite:///example.db
    # or journal storage for NFS-scale fleets:
    PYTHONPATH=src python examples/distributed_study.py --storage journal:///shared/example.journal
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

import repro.core as hpo


def objective(trial: hpo.Trial) -> float:
    x = trial.suggest_float("x", -5, 5)
    y = trial.suggest_float("y", -5, 5)
    for step in range(1, 9):  # intermediate values feed ASHA across workers
        partial = (x - 1) ** 2 + (y + 2) ** 2 + 2.0 / step
        trial.report(partial, step)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return (x - 1) ** 2 + (y + 2) ** 2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--storage", default="sqlite:////tmp/example_study.db")
    ap.add_argument("--study", default="distributed-example")
    ap.add_argument("--trials", type=int, default=20)
    ap.add_argument("--workers", type=int, default=0,
                    help="spawn N local worker processes (0 = run inline)")
    args = ap.parse_args()

    study = hpo.create_study(
        study_name=args.study,
        storage=args.storage,
        sampler=hpo.TPESampler(),
        pruner=hpo.SuccessiveHalvingPruner(),
        load_if_exists=True,  # elastic: join an existing study at any time
    )

    if args.workers > 0:
        dur = hpo.run_workers(
            args.workers, args.storage, args.study, objective,
            n_trials_per_worker=args.trials // args.workers,
            pruner_factory=lambda: hpo.SuccessiveHalvingPruner(),
        )
        print(f"{args.workers} workers finished in {dur:.2f}s")
    else:
        study.heartbeat_interval = 2.0  # fault tolerance: dead workers detected
        study.optimize(objective, n_trials=args.trials, catch=(Exception,))

    study.fail_stale_trials()
    print(f"total trials in study: {len(study.trials)}; best: {study.best_value:.5f} "
          f"at {study.best_params}")


if __name__ == "__main__":
    main()
