"""Distributed optimization exactly as in paper Fig. 7: run this script N
times (or with --workers N to spawn locally) against a shared storage URL.

    # terminal 1..N (or different nodes over a shared filesystem):
    PYTHONPATH=src python examples/distributed_study.py --storage sqlite:///example.db
    # or journal storage for NFS-scale fleets:
    PYTHONPATH=src python examples/distributed_study.py --storage journal:///shared/example.journal

No shared filesystem?  Serve the storage over TCP instead (--serve wraps the
backend in a StorageServer and hands workers its remote:// URL), or point
workers on other machines at an already-running server:

    # host A: serve a local sqlite file to the fleet
    PYTHONPATH=src python -m repro.core.storage.server sqlite:///example.db --port 9000
    # hosts B..N:
    PYTHONPATH=src python examples/distributed_study.py --storage remote://hostA:9000
    # or all-in-one on a single box:
    PYTHONPATH=src python examples/distributed_study.py --workers 4 --serve

Wire protocol v2 migration: nothing to do.  New clients probe the server
with a `hello` handshake on connect — against a v2 server the connection
switches to binary columnar frames (numpy buffers cross the wire raw, cache
refreshes arrive as contiguous column blocks); against an older JSON-only
server they fall back to v1 silently.  Old JSON clients never send the
probe, so they keep working unchanged against a new server.  To pin the old
wire for debugging: `RemoteStorage(url, protocol=1)` client-side or
`StorageServer(..., max_protocol=1)` / `--max-protocol 1` server-side.
For encrypted transport, serve with `--tls-cert/--tls-key`, dial
`remote+tls://host:port`, and give clients the CA via
`RemoteStorage(tls_ca=...)` or `$REPRO_STORAGE_TLS_CA`.

Live dashboard: add --dashboard to serve the browser UI next to the study
(five live views + fANOVA importances, revision-gated polling so an idle
study costs nothing), or run it standalone against any storage URL —
including a sharded pool:

    PYTHONPATH=src python examples/distributed_study.py --workers 4 --serve --dashboard
    # or, against an existing fleet:
    PYTHONPATH=src python -m repro.serve.dashboard_service --storage remote://hostA:9000,hostB:9000
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

import repro.core as hpo


def objective(trial: hpo.Trial) -> float:
    x = trial.suggest_float("x", -5, 5)
    y = trial.suggest_float("y", -5, 5)
    for step in range(1, 9):  # intermediate values feed ASHA across workers
        partial = (x - 1) ** 2 + (y + 2) ** 2 + 2.0 / step
        trial.report(partial, step)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return (x - 1) ** 2 + (y + 2) ** 2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--storage", default="sqlite:////tmp/example_study.db")
    ap.add_argument("--study", default="distributed-example")
    ap.add_argument("--trials", type=int, default=20)
    ap.add_argument("--workers", type=int, default=0,
                    help="spawn N local worker processes (0 = run inline)")
    ap.add_argument("--serve", action="store_true",
                    help="serve --storage over remote:// and hand workers the URL")
    ap.add_argument("--dashboard", action="store_true",
                    help="serve the live analytics dashboard next to the study")
    args = ap.parse_args()

    # inline run with --serve: host the backend ourselves so workers on other
    # machines can join this same study via the printed remote:// URL
    server = None
    storage = args.storage
    if args.serve and args.workers == 0:
        server = hpo.StorageServer(hpo.get_storage(args.storage)).start()
        storage = server.url
        print(f"serving {args.storage} at {server.url} — point other workers here")

    dash = None
    if args.dashboard:
        from repro.serve.dashboard_service import DashboardService

        dash = DashboardService(storage).start()
        print(f"dashboard: {dash.url}/study/{args.study}")

    study = hpo.create_study(
        study_name=args.study,
        storage=storage,
        sampler=hpo.TPESampler(),
        pruner=hpo.SuccessiveHalvingPruner(),
        load_if_exists=True,  # elastic: join an existing study at any time
    )

    if args.workers > 0:
        dur = hpo.run_workers(
            args.workers, args.storage, args.study, objective,
            n_trials_per_worker=args.trials // args.workers,
            pruner_factory=lambda: hpo.SuccessiveHalvingPruner(),
            serve_storage=args.serve,
        )
        print(f"{args.workers} workers finished in {dur:.2f}s")
    else:
        study.heartbeat_interval = 2.0  # fault tolerance: dead workers detected
        study.optimize(objective, n_trials=args.trials, catch=(Exception,))

    study.fail_stale_trials()
    print(f"total trials in study: {len(study.trials)}; best: {study.best_value:.5f} "
          f"at {study.best_params}")
    if dash is not None:
        dash.stop()
    if server is not None:
        # live telemetry surface: any RemoteStorage client (a dashboard, a
        # fleet health check) can pull the same payload over the wire with
        # RemoteStorage(url).get_server_metrics()
        m = server.get_server_metrics()
        print(f"server: {m['frames_in']} frames / {m['bytes_in']} bytes in, "
              f"{m['bytes_out']} bytes out over {m['uptime_s']:.1f}s")
        for name, row in sorted(m["methods"].items(), key=lambda kv: -kv[1]["calls"])[:5]:
            print(f"  {name:28s} x{row['calls']:<5d} p50={row['p50']*1e3:.2f}ms "
                  f"p99={row['p99']*1e3:.2f}ms")
        server.stop()


if __name__ == "__main__":
    main()
