"""Quickstart: the paper's Figure 1 example, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

import repro.core as hpo


def objective(trial: hpo.Trial) -> float:
    """Define-by-run: the search space is just Python control flow."""
    n_layers = trial.suggest_int("n_layers", 1, 4)
    widths = []
    for i in range(n_layers):
        widths.append(trial.suggest_int(f"n_units_l{i}", 4, 128, log=True))
    lr = trial.suggest_float("lr", 1e-5, 1e-1, log=True)
    activation = trial.suggest_categorical("activation", ["relu", "tanh"])

    # stand-in validation error with structure: prefers ~2 layers, wide-ish,
    # lr near 1e-2, relu
    err = 0.3 * abs(n_layers - 2)
    err += 0.2 * abs(np.log2(np.mean(widths)) - 5)
    err += 0.5 * abs(np.log10(lr) + 2)
    err += 0.1 * (activation == "tanh")
    return float(err + 0.01 * np.random.RandomState(trial.number).randn())


def main():
    study = hpo.create_study(sampler=hpo.TPESampler(seed=0))
    study.optimize(objective, n_trials=100)

    print(f"best value : {study.best_value:.4f}")
    print(f"best params: {study.best_params}")

    # deploy the best configuration through the SAME objective (paper §2.2)
    fixed = hpo.FixedTrial(study.best_params)
    print(f"replayed   : {objective(fixed):.4f}")

    # parameter importances + dashboard artifact
    print("importances:", {k: round(v, 3) for k, v in hpo.param_importances(study).items()})
    path = hpo.save_dashboard(study, "/tmp/quickstart_dashboard.html")
    print(f"dashboard  : {path}")


if __name__ == "__main__":
    main()
