"""Define-by-run HPO over the LM model zoo with ASHA pruning — the paper's
technique as a first-class feature of the training framework.

Each trial dynamically constructs an architecture (dense / mLSTM / mamba2 /
MoE family, depth, width, expert count...) and an optimizer config, trains it
with repro.train on synthetic data, reports eval losses to the ASHA pruner,
and stops early if outranked (paper Alg. 1, no repechage).

    PYTHONPATH=src python examples/tune_lm.py --trials 12
"""

import argparse
import sys

sys.path.insert(0, "src")

import repro.core as hpo
from repro.tune import LMTuneSpec, make_lm_objective


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=10)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--storage", default=None, help="e.g. sqlite:///tune.db for distributed")
    ap.add_argument("--study", default="tune-lm")
    args = ap.parse_args()

    spec = LMTuneSpec(total_steps=args.steps, eval_every=max(args.steps // 8, 1))
    study = hpo.create_study(
        study_name=args.study,
        storage=args.storage,
        sampler=hpo.TPESampler(seed=0, n_startup_trials=4),
        pruner=hpo.SuccessiveHalvingPruner(min_resource=1, reduction_factor=3),
        load_if_exists=True,
    )
    study.optimize(make_lm_objective(spec), n_trials=args.trials, catch=(Exception,))

    states = [t.state.name for t in study.trials]
    print(f"\ntrials: {len(states)}  complete: {states.count('COMPLETE')} "
          f"pruned: {states.count('PRUNED')}  failed: {states.count('FAIL')}")
    best = study.best_trial
    print(f"best loss {best.values[0]:.4f} with {best.params}")
    hpo.save_dashboard(study, "/tmp/tune_lm_dashboard.html")
    print("dashboard: /tmp/tune_lm_dashboard.html")


if __name__ == "__main__":
    main()
