"""Multi-objective optimization end to end: NSGA-II / MOTPE over a
two-objective accuracy-vs-latency trade-off, the engine-backed Pareto front,
and Pareto-aware pruning through the fused report path.

    PYTHONPATH=src python examples/multi_objective.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

import repro.core as hpo
from repro.core import moo


def objective(trial: hpo.Trial):
    """A model-selection stand-in: bigger/wider models are more accurate but
    slower — the classic accuracy-vs-latency Pareto trade-off."""
    n_layers = trial.suggest_int("n_layers", 1, 6)
    width = trial.suggest_int("width", 16, 512, log=True)
    lr = trial.suggest_float("lr", 1e-4, 1e-1, log=True)

    capacity = n_layers * np.log2(width)
    error = 1.0 / (1.0 + 0.15 * capacity) + 0.3 * abs(np.log10(lr) + 2.5) / 2.5
    latency_ms = 0.4 * n_layers * width / 64.0
    return [float(error), float(latency_ms)]  # minimize both


def staged_objective(trial: hpo.Trial):
    """Same trade-off, reported stage by stage: the ParetoPruner scalarizes
    each vector report so multi-objective trials prune mid-flight through
    the same fused report->prune round trip single-objective studies use."""
    err, lat = objective(trial)
    for step in range(1, 6):
        partial_err = err + (5 - step) * 0.08  # error anneals in as we train
        trial.report([partial_err, lat], step)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return [err, lat]


def show_front(study: hpo.Study, title: str) -> None:
    values, numbers = study.pareto_front()  # arrays straight off the engine
    hv = moo.hypervolume(
        moo.loss_matrix(values, study.directions), np.asarray([1.5, 25.0])
    )
    print(f"\n{title}: {len(numbers)} Pareto-optimal trials, hypervolume {hv:.3f}")
    order = np.argsort(values[:, 0])
    for err, lat in values[order][:8]:
        print(f"  error={err:6.3f}  latency={lat:7.2f}ms")


def main():
    for name, sampler in [
        ("nsga2", hpo.NSGAIISampler(population_size=16, seed=0)),
        ("motpe", hpo.TPESampler(seed=0, multi_objective=True, multivariate=True)),
    ]:
        study = hpo.create_study(
            directions=["minimize", "minimize"], sampler=sampler
        )
        # ask(n) waves: one sampler generation / one joint Parzen fit per wave
        study.optimize(objective, n_trials=96, ask_batch=16)
        show_front(study, f"{name} front")

    pruned_study = hpo.create_study(
        directions=["minimize", "minimize"],
        sampler=hpo.NSGAIISampler(population_size=16, seed=1),
        pruner=hpo.ParetoPruner(hpo.MedianPruner(n_startup_trials=8, n_warmup_steps=1)),
    )
    pruned_study.optimize(staged_objective, n_trials=60)
    n_pruned = len(
        pruned_study.get_trials(deepcopy=False, states=(hpo.TrialState.PRUNED,))
    )
    show_front(pruned_study, f"pruned study front ({n_pruned} trials pruned early)")


if __name__ == "__main__":
    main()
