"""Serving example: batched generation with prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --max-new 24
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import configs
from repro.models import init_model_params
from repro.serve import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", help="smoke config of this arch")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, capacity=128, slots=4, temperature=args.temperature)

    rng = np.random.RandomState(0)
    prompts = [
        rng.randint(0, cfg.vocab, size=rng.randint(4, 17)).astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n_tokens = sum(len(o) for o in outs)
    for i, o in enumerate(outs[:4]):
        print(f"req{i}: prompt={prompts[i][:6].tolist()}... -> {o[:12]}...")
    print(f"\n{n_tokens} tokens in {dt:.2f}s ({n_tokens/dt:.1f} tok/s, "
          f"{args.requests} requests, slots=4, greedy={args.temperature<=0})")


if __name__ == "__main__":
    main()
