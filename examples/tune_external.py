"""Tuning a non-ML black box (paper §6: HPL / RocksDB / FFmpeg pattern).

The objective shells out to an external process whose runtime depends on its
flags — here a self-contained stand-in that simulates a storage-engine
benchmark (the paper's RocksDB case: 30+ discrete/continuous knobs, noisy
runtime, pruning on incremental progress).

    PYTHONPATH=src python examples/tune_external.py --trials 40
"""

import argparse
import subprocess
import sys
import textwrap

sys.path.insert(0, "src")

import repro.core as hpo

SIMULATOR = textwrap.dedent(
    """
    import sys, math, random
    # "storage engine" whose throughput depends on its knobs
    block_kb, cache_mb, compress, threads, wal = (
        int(sys.argv[1]), int(sys.argv[2]), sys.argv[3], int(sys.argv[4]), sys.argv[5])
    rnd = random.Random(42)
    base = 380.0
    base *= 1.0 + 0.35 * abs(math.log2(block_kb) - 4) / 4        # sweet spot 16KB
    base *= 1.0 + 0.25 * abs(math.log2(cache_mb) - 8) / 8        # sweet spot 256MB
    base *= {"none": 1.15, "snappy": 1.0, "zstd": 0.92}[compress]
    base *= 1.0 + 0.15 * abs(threads - 8) / 8
    base *= 1.12 if wal == "sync" else 1.0
    # emit per-phase progress so the tuner can prune
    for phase in range(1, 5):
        print(f"phase {phase} elapsed {base * phase / 4 * (1 + 0.02*rnd.random()):.2f}")
    """
)


def objective(trial: hpo.Trial) -> float:
    block_kb = trial.suggest_categorical("block_kb", [4, 8, 16, 32, 64, 128])
    cache_mb = trial.suggest_int("cache_mb", 16, 4096, log=True)
    compress = trial.suggest_categorical("compression", ["none", "snappy", "zstd"])
    threads = trial.suggest_int("threads", 1, 32)
    wal = trial.suggest_categorical("wal", ["sync", "async"])

    proc = subprocess.run(
        [sys.executable, "-c", SIMULATOR, str(block_kb), str(cache_mb), compress,
         str(threads), wal],
        capture_output=True, text=True, timeout=60,
    )
    elapsed = None
    for i, line in enumerate(proc.stdout.splitlines()):
        elapsed = float(line.split()[-1])
        trial.report(elapsed, i + 1)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return elapsed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=40)
    args = ap.parse_args()
    study = hpo.create_study(
        sampler=hpo.TPESampler(seed=0),
        pruner=hpo.SuccessiveHalvingPruner(min_resource=1, reduction_factor=2),
    )
    study.optimize(objective, n_trials=args.trials, catch=(Exception,))
    states = [t.state.name for t in study.trials]
    print(f"explored {len(states)} configs ({states.count('PRUNED')} pruned)")
    print(f"default-ish runtime ~380s; best found {study.best_value:.1f}s with "
          f"{study.best_params}")


if __name__ == "__main__":
    main()
