"""End-to-end training driver: train an LM for a few hundred steps with the
full substrate (config system, data pipeline, optimizer, checkpointing,
auto-resume).

    # CPU-sized run (finishes in ~2 min):
    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200

    # ~100M-parameter run (real-hardware sized; works on CPU but slow):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # any assigned architecture at its production config (TPU-sized):
    PYTHONPATH=src python examples/train_lm.py --arch gemma2-9b --steps 100
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro import configs
from repro.models.config import BlockDef, ModelConfig
from repro.train import SyntheticLM, TrainConfig, Trainer


def preset_tiny() -> ModelConfig:
    return ModelConfig(
        name="tiny-8m", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=2048, superblock=(BlockDef(kind="attn"),),
        n_superblocks=4, q_chunk=64, ce_chunk=64,
    )


def preset_100m() -> ModelConfig:
    # ~100M params: 12L x 768d (GPT-2-small-like with GQA + SwiGLU)
    return ModelConfig(
        name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab=32000, superblock=(BlockDef(kind="attn"),),
        n_superblocks=12, q_chunk=128, ce_chunk=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--arch", default=None, help="assigned architecture id instead of preset")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    if args.arch:
        cfg = configs.get_config(args.arch)
    else:
        cfg = preset_tiny() if args.preset == "tiny" else preset_100m()

    from repro.models import count_params

    print(f"model: {cfg.name}  params: {count_params(cfg)/1e6:.1f}M")
    tcfg = TrainConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 1), total_steps=args.steps,
        eval_every=max(args.steps // 20, 1), checkpoint_every=max(args.steps // 4, 1),
    )
    workdir = args.workdir or tempfile.mkdtemp(prefix="train_lm_")
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=0)
    trainer = Trainer(cfg, tcfg, data, workdir=workdir)
    result = trainer.run()
    print(f"finished at step {result['step']}; eval losses: "
          + " ".join(f"{l:.3f}" for l in result["losses"]))
    print(f"checkpoints in {workdir} (re-run with --workdir {workdir} to resume)")


if __name__ == "__main__":
    main()
